//! Integration: the multi-switch hierarchical aggregation fabric —
//! `Topology::two_tier` routing invariants, star parity at `racks = 1`,
//! and end-to-end multi-rack simulations under every INA policy with
//! per-switch stats reporting.

use esa::config::ExperimentConfig;
use esa::net::{Topology, SWITCH_NODE};
use esa::sim::Simulation;
use esa::switch::policy::{all_ina, esa, hostps, PolicyHandle, PolicyRegistry};

fn cfg(policy: PolicyHandle, racks: usize, jobs: usize, workers: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::synthetic(policy, "microbench", jobs, workers);
    c.racks = racks;
    c.iterations = 2;
    c.seed = 77;
    c.jitter_max_ns = 20 * esa::USEC;
    for j in &mut c.jobs {
        j.tensor_bytes = Some(256 * 1024);
    }
    c
}

// ---------------------------------------------------------------------
// Topology routing invariants
// ---------------------------------------------------------------------

#[test]
fn every_host_reaches_its_rack_switch_in_one_hop() {
    for racks in [1usize, 2, 3, 4] {
        let t = Topology::two_tier(racks, 12);
        for h in racks..racks + 12 {
            let h = h as u32;
            let rack = t.parent_of(h);
            assert!(t.is_switch(rack), "parent of host {h} must be a switch");
            assert!((rack as usize) < racks);
            // first hop from a host is always its rack switch, for any dst
            for dst in 0..t.n_nodes() as u32 {
                if dst != h {
                    assert_eq!(t.next_hop(h, dst), rack, "host {h} -> {dst}");
                }
            }
        }
    }
}

#[test]
fn rack_switches_uplink_to_the_edge() {
    let racks = 4usize;
    let t = Topology::two_tier(racks, 16);
    for r in 1..racks as u32 {
        for dst in 0..t.n_nodes() as u32 {
            // anything not hanging off rack r (and not r itself) climbs up
            if dst != r && t.parent_of(dst) != r {
                assert_eq!(t.next_hop(r, dst), SWITCH_NODE, "rack {r} -> {dst}");
            }
        }
    }
    // and the edge fans back down to the right rack
    for h in racks as u32..(racks + 16) as u32 {
        if t.parent_of(h) != SWITCH_NODE {
            assert_eq!(t.next_hop(SWITCH_NODE, h), t.parent_of(h));
        }
    }
}

#[test]
fn star_equals_two_tier_with_one_rack() {
    // the degenerate fabric IS the star: identical shape, roles, parents,
    // next hops and link ids — this is what keeps racks=1 simulations
    // bit-compatible with the seed's single-switch runs
    for n_hosts in [1usize, 2, 5, 16] {
        let star = Topology::star(n_hosts);
        let tt = Topology::two_tier(1, n_hosts);
        assert_eq!(star.n_nodes(), tt.n_nodes());
        assert_eq!(star.n_switches(), tt.n_switches());
        assert_eq!(star.n_links(), tt.n_links());
        for a in 0..star.n_nodes() as u32 {
            assert_eq!(star.role(a), tt.role(a));
            assert_eq!(star.parent_of(a), tt.parent_of(a));
            for b in 0..star.n_nodes() as u32 {
                if a != b {
                    assert_eq!(star.next_hop(a, b), tt.next_hop(a, b), "{a}->{b}");
                    assert_eq!(star.link_id(a, b), tt.link_id(a, b));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// End-to-end multi-rack simulations
// ---------------------------------------------------------------------

#[test]
fn two_tier_completes_under_every_ina_policy() {
    let mut policies = all_ina();
    policies.push(hostps());
    for policy in policies {
        for racks in [2usize, 4] {
            let m = Simulation::run_experiment(cfg(policy.clone(), racks, 2, 4))
                .unwrap_or_else(|e| panic!("{policy:?} racks={racks}: {e}"));
            assert!(!m.truncated, "{policy:?} racks={racks} stalled");
            assert_eq!(m.jobs.len(), 2, "{policy:?} racks={racks}");
            for j in &m.jobs {
                assert_eq!(j.iterations, 2, "{policy:?} racks={racks}");
            }
        }
    }
}

#[test]
fn per_switch_stats_are_reported() {
    let mut sim = Simulation::new(cfg(esa(), 2, 2, 4)).unwrap();
    let m = sim.run();
    assert!(!m.truncated);
    // edge + one entry per rack switch
    assert_eq!(m.switches.len(), 3);
    assert_eq!(m.switches[0].tier, "edge");
    assert_eq!(m.switches[0].node, 0);
    assert_eq!(m.switches[1].tier, "rack");
    assert_eq!(m.switches[2].tier, "rack");
    // rack switches aggregated gradients and folded partials upward
    let rack_grads: u64 = m.switches[1..].iter().map(|s| s.stats.grad_pkts).sum();
    let rack_uplinks: u64 = m.switches[1..].iter().map(|s| s.stats.rack_uplinks).sum();
    assert!(rack_grads > 0, "rack switches must see the gradients");
    assert!(rack_uplinks > 0, "completed rack aggregations must fold upward");
    // the edge only ever sees rack partials, never raw gradients
    assert_eq!(m.switches[0].stats.grad_pkts, 0);
    assert!(m.switches[0].stats.rack_partial_pkts > 0);
    assert_eq!(m.switches[0].stats.rack_partial_pkts, rack_uplinks);
    // in-network aggregation did happen at both tiers
    assert!(m.switches[0].stats.completions > 0);
    // rack-level partial aggregation compresses the uplink: the edge
    // ingress is strictly smaller than the gradient volume (that is the
    // rack-scale INA win SwitchML/ATP report)
    assert!(
        m.switches[0].stats.rack_partial_pkts < rack_grads,
        "uplink must carry fewer packets than the workers pushed"
    );
    // accessor sugar
    assert_eq!(sim.rack_switches().len(), 2);
    let _ = sim.switch();
}

#[test]
fn racks_one_is_the_single_switch_star() {
    // The parity contract has three legs, each pinned somewhere concrete:
    // (1) an untouched config defaults to racks = 1, so pre-hierarchy
    //     experiments run exactly this path;
    // (2) the racks = 1 fabric is *structurally* the star — routing, link
    //     ids, roles (star_equals_two_tier_with_one_rack above);
    // (3) at runtime the single switch is a Root: zero hierarchy machinery
    //     engages — no uplinks, no rack partials, no downlink replication,
    //     results multicast straight to workers as in the seed.
    // (The rng stream order of the seed is additionally locked by the
    // deterministic-JCT tests in sim::tests and integration_sim.)
    assert_eq!(ExperimentConfig::default().racks, 1);
    let m = Simulation::run_experiment(cfg(esa(), 1, 2, 4)).unwrap();
    assert!(!m.truncated);
    assert_eq!(m.switches.len(), 1);
    assert_eq!(m.switches[0].tier, "root");
    let st = &m.switches[0].stats;
    assert_eq!(st.rack_uplinks, 0, "a root never uplinks");
    assert_eq!(st.rack_partial_pkts, 0, "no rack partials exist in a star");
    assert_eq!(st.rack_downlinks, 0, "no downlink replication in a star");
    assert!(st.completions > 0, "the root still aggregates normally");
}

// ---------------------------------------------------------------------
// Golden determinism: the event-core swap must be invisible
// ---------------------------------------------------------------------

/// The slab-backed 4-ary heap must be bit-identical to the pre-swap
/// binary-heap core for every policy at both fabric shapes. Two layers of
/// evidence per config:
///
/// 1. `enable_shadow()` runs the old `BinaryHeap` core in lockstep inside
///    the queue and panics on the first pop-order divergence — the
///    executable form of "before vs after the swap";
/// 2. two independent runs must agree on `sim_ns` / `events` /
///    `avg_jct_ms` to the bit.
///
/// Scope: this pins the *event-core* swap. Comparing against a pre-PR
/// checkout is additionally exact for every `racks = 1` config and for
/// all non-StrawCoin policies at `racks >= 2`; StrawCoin multi-rack runs
/// legitimately differ from pre-PR because the same PR renamespaces the
/// edge/rack-switch RNG labels its coin flips draw from (the one actor
/// class that samples switch randomness — see `sim::rng_stream`).
#[test]
fn golden_event_core_swap_is_bit_identical_for_all_policies() {
    for policy in all_ina() {
        for racks in [1usize, 4] {
            let run = || {
                let mut sim = Simulation::new(cfg(policy.clone(), racks, 2, 4)).unwrap();
                sim.net.queue.enable_shadow();
                sim.run()
            };
            let a = run();
            let b = run();
            assert!(!a.truncated, "{policy:?} racks={racks} stalled");
            assert_eq!(a.sim_ns, b.sim_ns, "{policy:?} racks={racks} sim_ns");
            assert_eq!(a.events, b.events, "{policy:?} racks={racks} events");
            assert_eq!(
                a.avg_jct_ms().to_bits(),
                b.avg_jct_ms().to_bits(),
                "{policy:?} racks={racks} avg_jct_ms must match to the bit"
            );
            assert_eq!(
                a.avg_transit_ns.to_bits(),
                b.avg_transit_ns.to_bits(),
                "{policy:?} racks={racks} avg_transit_ns must match to the bit"
            );
            assert_eq!(a.past_schedules, 0, "{policy:?} racks={racks} clamped a schedule");
        }
    }
}

/// 128 workers across the fabric: beyond the seed's rng collision point
/// (worker labels 199/200+ used to alias the edge and rack switches).
/// The run must complete and replay exactly.
#[test]
fn rng_streams_stay_disjoint_at_128_workers() {
    let mut c = ExperimentConfig::synthetic(esa(), "microbench", 16, 8);
    c.racks = 4;
    c.iterations = 1;
    c.seed = 33;
    c.jitter_max_ns = 20 * esa::USEC;
    for j in &mut c.jobs {
        j.tensor_bytes = Some(64 * 1024);
    }
    let a = Simulation::run_experiment(c.clone()).unwrap();
    let b = Simulation::run_experiment(c).unwrap();
    assert!(!a.truncated, "128-worker fabric stalled");
    assert_eq!(a.jobs.len(), 16);
    assert_eq!(a.sim_ns, b.sim_ns);
    assert_eq!(a.events, b.events);
    assert_eq!(a.avg_jct_ms().to_bits(), b.avg_jct_ms().to_bits());
}

#[test]
fn two_tier_is_deterministic_across_runs() {
    let a = Simulation::run_experiment(cfg(esa(), 3, 2, 6)).unwrap();
    let b = Simulation::run_experiment(cfg(esa(), 3, 2, 6)).unwrap();
    assert!(!a.truncated);
    assert_eq!(a.events, b.events);
    assert_eq!(a.sim_ns, b.sim_ns);
}

#[test]
fn esa_preemption_operates_at_both_tiers_under_contention() {
    // structured layered jobs on a scarce pool force collisions; with 2
    // racks the collision machinery (preempt or passthrough) must engage
    // somewhere in the fabric and the run must still complete
    let mut c = ExperimentConfig::synthetic(esa(), "dnn_a", 4, 4);
    c.racks = 2;
    c.iterations = 2;
    c.seed = 5;
    c.switch.memory_bytes = 256 * 1024;
    for j in &mut c.jobs {
        j.tensor_bytes = Some(2 * 1024 * 1024);
    }
    let m = Simulation::run_experiment(c).unwrap();
    assert!(!m.truncated);
    let collisions: u64 = m
        .switches
        .iter()
        .map(|s| s.stats.preemptions + s.stats.passthroughs)
        .sum();
    assert!(collisions > 0, "scarce pool must force collisions in the fabric");
}

#[test]
fn two_tier_values_mode_aggregation_is_exact() {
    // real payloads through a 2-rack ESA fabric: the collected sums must
    // equal the wrapping reference — rack partial folding is lossless
    let mut c = cfg(esa(), 2, 1, 4);
    c.iterations = 1;
    c.jobs[0].tensor_bytes = Some(64 * 1024);
    let mut sim = Simulation::new(c).unwrap();
    let frags = 64 * 1024 / 256;
    let lanes = 64;
    let mut reference = vec![0i32; frags * lanes];
    for w in 0..4 {
        let payload: Vec<i32> = (0..frags * lanes)
            .map(|i| (i as i32).wrapping_mul(17).wrapping_add(w as i32))
            .collect();
        esa::util::fixed::agg_add_slice(&mut reference, &payload);
        sim.worker_mut(0, w).set_payload(std::sync::Arc::new(payload));
    }
    let m = sim.run();
    assert!(!m.truncated);
    let collected = sim.worker_mut(0, 0).take_collected().unwrap();
    assert_eq!(collected, reference, "hierarchical aggregation must be exact");
}

#[test]
fn two_tier_recovers_from_loss() {
    // the reminder machinery composes across tiers: worker reminder → PS →
    // edge flush + fan-down → rack flushes → NACK selective retransmission
    let mut c = cfg(esa(), 2, 1, 4);
    c.net.loss_prob = 0.005;
    let m = Simulation::run_experiment(c).unwrap();
    assert!(!m.truncated, "two-tier loss recovery must converge");
    assert_eq!(m.jobs[0].iterations, 2);
}

#[test]
fn atp_two_tier_recovers_from_loss() {
    let mut c = cfg(PolicyRegistry::resolve("atp").unwrap(), 2, 1, 4);
    c.net.loss_prob = 0.005;
    let m = Simulation::run_experiment(c).unwrap();
    assert!(!m.truncated, "ATP resend semantics must survive the hierarchy");
}

#[test]
fn more_racks_do_not_break_structured_jobs() {
    // dnn jobs with layers + priorities across a 4-rack fabric
    let mut c = ExperimentConfig::synthetic(esa(), "dnn_a", 2, 8);
    c.racks = 4;
    c.iterations = 2;
    c.seed = 9;
    for j in &mut c.jobs {
        j.tensor_bytes = Some(1024 * 1024);
    }
    let m = Simulation::run_experiment(c).unwrap();
    assert!(!m.truncated);
    assert_eq!(m.jobs.len(), 2);
    assert_eq!(m.switches.len(), 5, "edge + 4 racks");
}

// ---------------------------------------------------------------------
// Policy-parity matrix: the trait redesign must be byte-invisible
// ---------------------------------------------------------------------

/// All six built-ins × racks {1, 4} through the `SchedulerPolicy` trait
/// dispatch. Two legs per cell:
///
/// 1. the registry path (`PolicyRegistry::resolve("<key>")`) and the
///    direct-constructor path must produce bit-identical metrics — policy
///    identity is behavioral, not an enum branch;
/// 2. each run replays exactly (the same determinism contract the
///    pre-redesign goldens in this file and in `integration_sweep.rs` /
///    `integration_churn.rs` pin — those suites run unchanged against the
///    trait dispatch, which is the before/after golden parity).
#[test]
fn policy_parity_matrix_trait_dispatch_is_bit_identical() {
    let mut policies = all_ina();
    policies.push(hostps());
    for policy in policies {
        for racks in [1usize, 4] {
            let direct = Simulation::run_experiment(cfg(policy.clone(), racks, 2, 4))
                .unwrap_or_else(|e| panic!("{policy:?} racks={racks}: {e}"));
            let resolved = PolicyRegistry::resolve(policy.key())
                .unwrap_or_else(|e| panic!("{policy:?} must be registered: {e}"));
            let via_registry =
                Simulation::run_experiment(cfg(resolved, racks, 2, 4)).unwrap();
            assert!(!direct.truncated, "{policy:?} racks={racks} stalled");
            assert_eq!(direct.sim_ns, via_registry.sim_ns, "{policy:?} racks={racks}");
            assert_eq!(direct.events, via_registry.events, "{policy:?} racks={racks}");
            assert_eq!(
                direct.avg_jct_ms().to_bits(),
                via_registry.avg_jct_ms().to_bits(),
                "{policy:?} racks={racks}: registry resolution must not change a single bit"
            );
            assert_eq!(
                direct.avg_transit_ns.to_bits(),
                via_registry.avg_transit_ns.to_bits(),
                "{policy:?} racks={racks}"
            );
            let (a, b) = (&direct.switches, &via_registry.switches);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.stats.preemptions, y.stats.preemptions, "{policy:?} racks={racks}");
                assert_eq!(x.stats.completions, y.stats.completions, "{policy:?} racks={racks}");
                assert_eq!(x.stats.passthroughs, y.stats.passthroughs, "{policy:?} racks={racks}");
            }
        }
    }
}

/// `esa-k` (the extension-point proof) composes with the fabric exactly
/// like ESA: with the gate pinned to the driver default (base RTT =
/// 10 µs), `esa-k=10000` is bit-identical to `esa`; with an effectively
/// infinite gate, aging never fires and behavior may legitimately drift.
#[test]
fn esa_k_with_base_rtt_gate_matches_esa_bit_for_bit() {
    for racks in [1usize, 4] {
        let esa_run = Simulation::run_experiment(cfg(esa(), racks, 2, 4)).unwrap();
        let k_run = Simulation::run_experiment(cfg(
            PolicyRegistry::resolve("esa-k=10000").unwrap(),
            racks,
            2,
            4,
        ))
        .unwrap();
        assert!(!esa_run.truncated && !k_run.truncated);
        assert_eq!(esa_run.sim_ns, k_run.sim_ns, "racks={racks}");
        assert_eq!(esa_run.events, k_run.events, "racks={racks}");
        assert_eq!(
            esa_run.avg_jct_ms().to_bits(),
            k_run.avg_jct_ms().to_bits(),
            "racks={racks}: a 10 µs gate IS the ESA default"
        );
    }
    // the bare default (20 µs) still completes end-to-end
    let m = Simulation::run_experiment(cfg(PolicyRegistry::resolve("esa-k").unwrap(), 2, 2, 4))
        .unwrap();
    assert!(!m.truncated, "esa-k default gate stalled");
}
