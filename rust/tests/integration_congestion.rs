//! Integration: the contention-aware network model (DESIGN.md §15) —
//! fixed-window parity with the pre-contention engine, byte determinism
//! of cross-traffic grids across thread counts, and the committed incast
//! demo showing a real congestion signal with policy-separated JCTs.

use esa::config::ExperimentConfig;
use esa::net::congestion::CcRegistry;
use esa::sim::sweep::{run_sweep, SweepConfig};
use esa::sim::Simulation;
use esa::switch::policy::PolicyRegistry;

/// Parity pin for the controller plumbing itself: resolving
/// `fixed-window` through the registry (the `--cc` CLI path) must be
/// indistinguishable from the default-constructed config, down to the
/// event count.
#[test]
fn registry_resolved_fixed_window_matches_the_default_config() {
    let mk = || {
        let policy = PolicyRegistry::resolve("esa").unwrap();
        ExperimentConfig::synthetic(policy, "microbench", 2, 4)
    };
    let baseline = Simulation::new(mk()).unwrap().run();
    let mut cfg = mk();
    cfg.cc = CcRegistry::resolve("fixed-window").unwrap();
    let resolved = Simulation::new(cfg).unwrap().run();
    assert_eq!(baseline.sim_ns, resolved.sim_ns);
    assert_eq!(baseline.events, resolved.events);
    assert_eq!(baseline.ecn_marked, resolved.ecn_marked);
    assert_eq!(baseline.dropped, resolved.dropped);
    assert_eq!(baseline.tail_drops, 0, "default config has unbounded queues");
}

/// The congestion-gate CI contract, in-process: a cc x intensity grid
/// with finite queues and Poisson cross-traffic serializes to identical
/// bytes across two runs AND across thread counts.
#[test]
fn cross_traffic_grid_is_byte_identical_across_thread_counts() {
    let cfg = SweepConfig::parse_str(
        r#"
        name = "incast_it"
        iterations = 1
        [axes]
        policies = ["esa", "atp"]
        workers = [8]
        jobs = [2]
        seeds = [42]
        tensor_kb = [256]
        cc = ["fixed-window", "newreno"]
        xtraffic_intensity = [0.0, 0.6]
        [base]
        queue_kb = 16
        [cross_traffic]
        burst_bytes = 8192
        [models]
        names = ["microbench"]
        "#,
    )
    .unwrap();
    let a = run_sweep(&cfg, 1).unwrap();
    let b = run_sweep(&cfg, 4).unwrap();
    let c = run_sweep(&cfg, 4).unwrap();
    assert_eq!(a.to_json(), b.to_json(), "threads 1 vs 4 must serialize identically");
    assert_eq!(b.to_json(), c.to_json(), "two identical runs must serialize identically");
    assert_eq!(a.to_csv(), b.to_csv(), "CSV must be byte-stable too");

    // 2 policies x 2 cc x 2 intensities, intensity expanding innermost
    assert_eq!(a.cells.len(), 8);
    for cell in &a.cells {
        assert_eq!(cell.truncated, 0, "{:?} stalled", cell.spec);
    }
    // the loaded cells actually hit the contention model; the quiet
    // fixed-window cells stay clean (the parity regime)
    let loaded: u64 = a
        .cells
        .iter()
        .filter(|c| c.spec.xtraffic > 0.0)
        .map(|c| c.ecn_marked + c.tail_drops)
        .sum();
    assert!(loaded > 0, "cross-traffic cells show no congestion signal");
    for cell in a.cells.iter().filter(|c| {
        c.spec.xtraffic == 0.0 && c.spec.cc.key() == "fixed-window"
    }) {
        assert_eq!(cell.tail_drops, 0, "{:?}", cell.spec);
    }
}

/// The committed demo config is the acceptance-criteria artifact: the
/// loaded regime must produce a nonzero congestion signal and a JCT
/// ranking that actually separates the policies.
#[test]
fn committed_incast_demo_shows_contention_and_separates_policies() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/incast_demo.toml");
    let cfg = SweepConfig::from_file(&path).unwrap();
    cfg.validate().unwrap();
    // 3 policies x 2 cc x 2 intensities
    assert_eq!(cfg.expand().len(), 12);
    let report = run_sweep(&cfg, 4).unwrap();
    let loaded: Vec<_> = report.cells.iter().filter(|c| c.spec.xtraffic > 0.0).collect();
    assert!(
        loaded.iter().any(|c| c.ecn_marked + c.tail_drops > 0),
        "demo grid produced no ECN marks or drops under cross-traffic"
    );
    // policy-separated ranking under incast: the loaded newreno cells
    // must not all land on the same JCT
    let mut jcts: Vec<f64> = loaded
        .iter()
        .filter(|c| c.spec.cc.key() == "newreno")
        .map(|c| c.jct_ms_mean)
        .collect();
    jcts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert!(jcts.len() >= 3, "expected one loaded newreno cell per policy");
    assert!(
        jcts.last().unwrap() > jcts.first().unwrap(),
        "policies are indistinguishable under incast: {jcts:?}"
    );
    // congestion fields ride the artifact only when the model engages
    let json = report.to_json();
    assert!(json.contains("\"cc\": \"newreno\""), "{}", &json[..200.min(json.len())]);
    assert!(json.contains("\"tail_drops\""));
}

/// Unknown controller names die with the registry's catalog, same as
/// unknown policies — the CLI surfaces this string verbatim.
#[test]
fn unknown_cc_name_lists_the_registered_controllers() {
    let err = CcRegistry::resolve("vegas").unwrap_err().to_string();
    assert!(err.contains("unknown congestion controller"), "{err}");
    assert!(err.contains("fixed-window"), "{err}");
    assert!(err.contains("newreno"), "{err}");
}
