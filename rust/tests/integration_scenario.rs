//! Fault-scenario integration tests (DESIGN.md §13): admission edge
//! cases under injected faults, checked through the structured event log
//! rather than ad-hoc counters.
//!
//! The workload mirrors `integration_churn.rs`'s contended setup — a
//! whole-pool static region (936 slots out of a 256 KB pool) so SwitchML
//! serializes tenants through the FIFO admission queue — and scripts a
//! switch crash while that queue is populated. The captured JSON-lines
//! event log is then *replayed* as data: admission order, region
//! grant/revoke pairing, and byte-stability across runs and thread
//! counts are all asserted from the log itself.

use esa::config::{ChurnKnobs, FaultKind, FaultSpec};
use esa::packet::Packet;
use esa::sim::events::diff_logs;
use esa::sim::scenario::{run_scenario, PolicyScenario, ScenarioReport, ScenarioSpec};
use esa::switch::policy::{atp, esa, switchml, PolicyHandle};
use esa::switch::{JobWiring, Switch};
use esa::util::rng::Rng;
use esa::USEC;

/// A contended scenario: six 64 KB jobs arriving at 50k/s into a 256 KB
/// pool with a whole-pool region (single tenant at a time for SwitchML,
/// so a FIFO queue exists), and a switch crash scripted mid-queue.
fn contended(policies: Vec<PolicyHandle>) -> ScenarioSpec {
    let mut spec = ScenarioSpec::quick();
    spec.name = "itest".into();
    spec.policies = policies;
    spec.n_jobs = 6;
    spec.rate_per_sec = 50_000.0;
    spec.seed = 2026;
    spec.knobs = ChurnKnobs { sample_tick_ns: 10 * USEC, region_slots: 936 };
    spec.faults = vec![FaultSpec { at_ns: 60 * USEC, kind: FaultKind::SwitchCrash }];
    spec
}

fn policy<'a>(report: &'a ScenarioReport, key: &str) -> &'a PolicyScenario {
    report
        .per_policy
        .iter()
        .find(|p| p.policy().key() == key)
        .unwrap_or_else(|| panic!("policy {key} missing from report"))
}

/// The `kind` tag of one JSON-lines event.
fn kind(line: &str) -> &str {
    line.split_once("\"kind\":\"")
        .and_then(|(_, rest)| rest.split_once('"'))
        .map(|(k, _)| k)
        .unwrap_or_else(|| panic!("no kind in event line: {line}"))
}

/// An unsigned integer field of one JSON-lines event.
fn num(line: &str, key: &str) -> u64 {
    let pat = format!("\"{key}\":");
    let at = line.find(&pat).unwrap_or_else(|| panic!("no {key} in event line: {line}"));
    let digits: String =
        line[at + pat.len()..].chars().take_while(char::is_ascii_digit).collect();
    digits.parse().unwrap_or_else(|_| panic!("bad {key} in event line: {line}"))
}

/// The `"region":[start,len]` pair, or `None` for `"region":null`.
fn region(line: &str) -> Option<(u64, u64)> {
    let at = line.find("\"region\":[")?;
    let body = line[at + "\"region\":[".len()..].split_once(']')?.0;
    let (s, l) = body.split_once(',')?;
    Some((s.parse().ok()?, l.parse().ok()?))
}

#[test]
fn fifo_admission_order_is_preserved_across_the_switch_restart() {
    let report = run_scenario(&contended(vec![switchml()]), 1).unwrap();
    let p = policy(&report, "switchml");
    let ch = p.churn.metrics.churn.as_ref().expect("churn mode metrics");
    assert_eq!(
        ch.region_slots, ch.pool_slots_per_stage,
        "whole-pool region premise: one tenant at a time"
    );
    assert!(p.churn.peak_queue >= 1, "contended trace must form a queue");
    assert!(
        p.event_log.contains("\"kind\":\"job_queued\""),
        "queueing must show up in the event log"
    );

    // Replay the log: with a single-tenant region the *first* admission
    // of each job must happen in exact arrival order, and the crash's
    // re-admissions (second admissions of displaced jobs) must not
    // perturb that order.
    let mut arrival_order = Vec::new();
    let mut first_admit_order = Vec::new();
    let mut total_admits = 0u64;
    for line in p.event_log.lines() {
        match kind(line) {
            "job_arrived" => arrival_order.push(num(line, "job")),
            "job_admitted" => {
                total_admits += 1;
                let j = num(line, "job");
                if !first_admit_order.contains(&j) {
                    first_admit_order.push(j);
                }
            }
            _ => {}
        }
    }
    assert_eq!(first_admit_order, arrival_order, "FIFO order broken across the restart");

    let restart = p
        .event_log
        .lines()
        .find(|l| kind(l) == "switch_restarted")
        .expect("the scripted crash must fire mid-run");
    let displaced = num(restart, "displaced");
    let readmitted = num(restart, "readmitted");
    assert_eq!(
        readmitted, displaced,
        "a whole-pool displaced tenant always re-fits the wiped allocator"
    );
    assert_eq!(
        total_admits,
        arrival_order.len() as u64 + readmitted,
        "admissions = one per arrival + one re-admission per displaced job"
    );
    assert_eq!(p.churn.unfinished, 0, "every job must complete despite the crash");
}

#[test]
fn event_log_replay_shows_no_double_grants_and_disjoint_regions() {
    let report = run_scenario(&contended(vec![switchml()]), 1).unwrap();
    let p = policy(&report, "switchml");
    // Replay grant/revoke pairing from the log: a job never holds two
    // live grants (revoke + re-admit is the only regrant path), live
    // regions never overlap, and the run ends with the pool fully
    // returned.
    let mut live: Vec<(u64, (u64, u64))> = Vec::new();
    let mut grants = 0u64;
    for line in p.event_log.lines() {
        match kind(line) {
            "job_admitted" => {
                let Some((start, len)) = region(line) else { continue };
                grants += 1;
                let j = num(line, "job");
                assert!(
                    live.iter().all(|&(held, _)| held != j),
                    "double grant: job {j} re-admitted while its region is live: {line}"
                );
                for &(other, (s, l)) in &live {
                    assert!(
                        start + len <= s || s + l <= start,
                        "grant [{start},{len}) for job {j} overlaps job {other}'s [{s},{l})"
                    );
                }
                live.push((j, (start, len)));
            }
            "region_revoked" => {
                let j = num(line, "job");
                let at = live
                    .iter()
                    .position(|&(held, _)| held == j)
                    .unwrap_or_else(|| panic!("revoke without a live grant: {line}"));
                live.remove(at);
            }
            _ => {}
        }
    }
    assert!(
        grants >= report.arrivals.len() as u64,
        "every arrival must receive at least one region grant, got {grants}"
    );
    assert!(live.is_empty(), "grants still live at end of run: {live:?}");
}

#[test]
fn stale_stragglers_into_a_wiped_revoked_region_drop() {
    // Unit-level mirror of the crash path's worst case: a displaced
    // tenant whose region is *not* re-granted (it lost the post-crash
    // re-admission) still has packets in flight, slot-addressed into the
    // wiped pool. They must drop — re-occupying would resurrect exactly
    // the stale partials the crash wipe reclaimed.
    let wiring = vec![
        JobWiring { ps: 10, workers: vec![1, 2], fan_in: 2, fan_in_total: 2, packet_bytes: 306 },
        JobWiring { ps: 11, workers: vec![3, 4], fan_in: 2, fan_in_total: 2, packet_bytes: 306 },
    ];
    let mut sw = Switch::new(0, switchml(), 64, wiring, Rng::new(1));
    sw.enable_churn(2);
    sw.grant_region(0, 0, 32);
    let slot = sw.slot_index(0, 5); // addressed under the pre-crash grant
    let mut out = Vec::new();
    let mut p = Packet::gradient(0, 5, 0, 1, 2, 0, 1, 0, 306);
    p.agg_index = slot;
    sw.handle(10, p, &mut out);
    assert_eq!(sw.occupied_slots(), 1, "worker 0's partial is resident pre-crash");

    // the crash wipes the live partial exactly once, then the control
    // plane revokes the displaced tenant's region
    assert_eq!(sw.crash_wipe(20), 1);
    assert_eq!(sw.crash_wipe(21), 0, "wipe accounting is exactly-once");
    sw.revoke_region(0);

    // worker 1's straggler retransmit lands in the wiped, unowned region
    let mut late = Packet::gradient(0, 5, 1, 2, 2, 0, 2, 0, 306);
    late.agg_index = slot;
    sw.handle(30, late, &mut out);
    assert_eq!(sw.stats.stale_drops, 1, "stale straggler must drop, not re-occupy");
    assert_eq!(sw.occupied_slots(), 0);
    assert!(out.is_empty(), "a dropped straggler must not emit packets");
}

#[test]
fn scenario_artifacts_and_event_logs_are_byte_stable_across_runs_and_threads() {
    let spec = contended(vec![esa(), atp(), switchml()]);
    let first = run_scenario(&spec, 1).unwrap();
    let replay = run_scenario(&spec, 8).unwrap();
    assert_eq!(first.to_json(), replay.to_json(), "artifact bytes must not depend on threads");
    for (a, b) in first.per_policy.iter().zip(&replay.per_policy) {
        assert_eq!(
            diff_logs(&a.event_log, &b.event_log),
            None,
            "{}: captured log must diff empty against its replay",
            a.policy().name()
        );
        assert_eq!(a.event_digest, b.event_digest);
        assert_eq!(a.churn.unfinished, 0, "{}: crash must not strand jobs", a.policy().name());
    }

    // File round-trip: written artifacts carry the identical bytes.
    let dir = std::env::temp_dir().join(format!("esa-scenario-itest-{}", std::process::id()));
    let (json_path, log_paths) = first.write(&dir).unwrap();
    assert_eq!(std::fs::read_to_string(&json_path).unwrap(), first.to_json());
    assert_eq!(log_paths.len(), first.per_policy.len());
    for (path, p) in log_paths.iter().zip(&first.per_policy) {
        assert_eq!(&std::fs::read_to_string(path).unwrap(), &p.event_log);
    }
    let _ = std::fs::remove_dir_all(&dir);
}
