//! Integration: the PJRT runtime + end-to-end trainer against the real
//! AOT artifacts. These tests need `make artifacts`; they *fail* with a
//! clear message when artifacts are absent (CI runs `make test`, which
//! builds them first).

use esa::runtime::{ArtifactDir, Engine, HostTensor};
use esa::switch::policy::{atp, esa, hostps};
use esa::train::{Trainer, TrainerCfg};
use esa::util::fixed;

fn engine() -> Option<Engine> {
    let dir = ArtifactDir::default_location();
    if !dir.exists("train_step") {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::with_dir(dir).expect("PJRT init"))
}

#[test]
fn loads_and_validates_all_artifacts() {
    let Some(engine) = engine() else { return };
    for name in ["train_step", "fwd_loss", "aggregate", "apply_update"] {
        let g = engine.load(name).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(g.meta.name, name);
        assert!(!g.meta.inputs.is_empty());
        assert!(!g.meta.outputs.is_empty());
    }
}

#[test]
fn rust_fixed_point_matches_pallas_aggregate_kernel() {
    // the bit-compatibility contract between util::fixed and the L1
    // kernel: aggregate(random i32s) must equal the rust wrapping sum
    let Some(engine) = engine() else { return };
    let agg = engine.load("aggregate").unwrap();
    let n = agg.meta.extra_u64("n_workers").unwrap() as usize;
    let flat = agg.meta.extra_u64("flat_len").unwrap() as usize;
    let mut rng = esa::util::rng::Rng::new(42);
    let mut stacked = vec![0i32; n * flat];
    let mut mask = vec![0i32; n];
    let mut reference = vec![0i32; flat];
    for w in 0..n {
        mask[w] = if w % 3 == 2 { 0 } else { 1 }; // partial-mask case
        for i in 0..flat {
            stacked[w * flat + i] = rng.uniform(-1e9, 1e9) as i32;
        }
        if mask[w] == 1 {
            let row = stacked[w * flat..(w + 1) * flat].to_vec();
            fixed::agg_add_slice(&mut reference, &row);
        }
    }
    let outs = agg
        .execute(&[HostTensor::I32(stacked), HostTensor::I32(mask)])
        .unwrap();
    assert_eq!(outs[0].as_i32().unwrap(), &reference[..], "kernel != wrapping sum");
}

#[test]
fn train_step_outputs_quantized_clipped_gradients() {
    let Some(engine) = engine() else { return };
    let ts = engine.load("train_step").unwrap();
    let flat = ts.meta.extra_u64("flat_len").unwrap() as usize;
    let vocab = ts.meta.extra_u64("vocab").unwrap() as i64;
    let batch = ts.meta.extra_u64("batch").unwrap() as usize;
    let seq = ts.meta.extra_u64("seq_len").unwrap() as usize;
    let params = engine.dir.load_f32_blob("init_params.f32").unwrap();
    assert_eq!(params.len(), flat);
    let tokens: Vec<i32> = (0..batch * (seq + 1)).map(|i| (i as i64 % vocab) as i32).collect();
    let outs = ts
        .execute(&[HostTensor::F32(params), HostTensor::I32(tokens)])
        .unwrap();
    let loss = outs[0].scalar_f32().unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    // gradient clipped to unit norm ⇒ |q| <= 2^SCALE_BITS
    let qg = outs[1].as_i32().unwrap();
    assert_eq!(qg.len(), flat);
    let max = qg.iter().map(|v| v.unsigned_abs()).max().unwrap();
    assert!(max <= 1 << fixed::SCALE_BITS, "clip violated: {max}");
}

#[test]
fn apply_update_moves_parameters() {
    let Some(engine) = engine() else { return };
    let au = engine.load("apply_update").unwrap();
    let flat = au.meta.extra_u64("flat_len").unwrap() as usize;
    let lr = au.meta.extra_f64("lr").unwrap() as f32;
    let params = vec![1.0f32; flat];
    // aggregated gradient of quantized 0.5 from 2 workers
    let q_half = fixed::quantize(0.5);
    let agg = vec![q_half.wrapping_mul(2); flat];
    let outs = au
        .execute(&[
            HostTensor::F32(params),
            HostTensor::I32(agg),
            HostTensor::F32(vec![2.0]),
        ])
        .unwrap();
    let new = outs[0].as_f32().unwrap();
    // p' = p - lr * mean = 1 - lr*0.5
    let expect = 1.0 - lr * 0.5;
    assert!((new[0] - expect).abs() < 1e-4, "{} vs {expect}", new[0]);
}

#[test]
fn short_training_reduces_loss_and_crosschecks() {
    let Some(engine) = engine() else { return };
    let cfg = TrainerCfg {
        n_workers: 2,
        steps: 8,
        policy: esa(),
        seed: 3,
        crosscheck_every: 4, // exercises the Pallas cross-check path
        log_every: 0,
    };
    let mut t = Trainer::new(&engine, cfg).unwrap();
    let hist = t.run().unwrap();
    assert_eq!(hist.len(), 8);
    let first = hist.first().unwrap().mean_loss;
    let last = hist.last().unwrap().mean_loss;
    assert!(
        last < first,
        "loss must decrease over 8 INA-aggregated steps: {first} -> {last}"
    );
}

#[test]
fn fig6a_equivalence_ina_vs_plain_ps_training() {
    // Fig. 6a's claim: ESA does not affect training. Because the INA path
    // is numerically exact (integer summation is associative), the ESA
    // and no-INA (BytePS) parameter trajectories must be IDENTICAL.
    let Some(engine) = engine() else { return };
    let mk = |policy| {
        let cfg = TrainerCfg {
            n_workers: 2,
            steps: 3,
            policy,
            seed: 11,
            crosscheck_every: 0,
            log_every: 0,
        };
        let mut t = Trainer::new(&engine, cfg).unwrap();
        t.run().unwrap();
        t.params().to_vec()
    };
    let esa = mk(esa());
    let byteps = mk(hostps());
    assert_eq!(esa.len(), byteps.len());
    let diffs = esa.iter().zip(&byteps).filter(|(a, b)| a != b).count();
    assert_eq!(diffs, 0, "{diffs} params diverged between ESA and no-INA");
}

#[test]
fn training_through_atp_matches_esa_numerically() {
    let Some(engine) = engine() else { return };
    let mk = |policy| {
        let cfg = TrainerCfg {
            n_workers: 2,
            steps: 2,
            policy,
            seed: 21,
            crosscheck_every: 0,
            log_every: 0,
        };
        let mut t = Trainer::new(&engine, cfg).unwrap();
        t.run().unwrap();
        t.params().to_vec()
    };
    assert_eq!(mk(esa()), mk(atp()));
}
