//! Property-based topology invariants, on the same from-scratch
//! mini-framework as `prop_invariants.rs` (proptest is unavailable
//! offline): deterministic seeded random-case sweeps with failing-seed
//! reporting. On failure, re-run with the printed seed.
//!
//! The routing oracle is [`Topology::walk`]: every legal endpoint pair
//! must reach its destination within the fabric diameter, loop-free,
//! on every topology shape the simulator can build (star, two-tier,
//! 3-tier fat-tree across oversubscription ratios).

use std::collections::HashSet;

use esa::net::topology::{NodeRole, Topology};
use esa::util::rng::Rng;
use esa::NodeId;

/// Run `cases` random cases; panic with the failing seed on error.
fn prop(name: &str, cases: u64, mut f: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = 0xE5A1_0000 + case;
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property `{name}` failed at seed {seed:#x} (case {case})");
            std::panic::resume_unwind(e);
        }
    }
}

/// A random topology from the full shape grid the simulator uses:
/// star, two-tier, or fat-tree with k = 4 and a random oversubscription.
fn random_topology(rng: &mut Rng) -> Topology {
    match rng.next_below(3) {
        0 => Topology::star(rng.uniform_u64(1, 32) as usize),
        1 => Topology::two_tier(
            rng.uniform_u64(1, 8) as usize,
            rng.uniform_u64(1, 8) as usize,
        ),
        _ => Topology::fat_tree(
            rng.uniform_u64(1, 8) as usize,
            rng.uniform_u64(1, 8) as usize,
            4,
            rng.uniform_u64(1, 4) as usize,
        ),
    }
}

/// Hosts plus ToRs: everything the simulator addresses packets to
/// (workers, the PS, and rack switches receiving `RackPartial`s).
fn endpoints(topo: &Topology) -> Vec<NodeId> {
    (0..topo.n_nodes() as NodeId)
        .filter(|&n| topo.role(n) == NodeRole::Host || (topo.is_switch(n) && !topo.is_fabric(n)))
        .collect()
}

/// Every legal endpoint pair routes to its destination within the
/// fabric diameter (6 hops for the 3-tier fat-tree, with slack), and
/// the walk never revisits a node — the no-routing-loop invariant.
#[test]
fn prop_walks_terminate_within_the_diameter_and_are_loop_free() {
    prop("walk-termination", 40, |rng| {
        let topo = random_topology(rng);
        let eps = endpoints(&topo);
        for &src in &eps {
            for &dst in &eps {
                if src == dst {
                    continue;
                }
                let (path, hops) = topo.walk(src, dst, 8).unwrap_or_else(|e| {
                    panic!("walk {src} -> {dst} failed on {topo:?}: {e}")
                });
                assert_eq!(*path.last().unwrap(), dst);
                assert!(hops <= 6, "{src} -> {dst} took {hops} hops: {path:?}");
                let mut seen: HashSet<NodeId> = HashSet::from([src]);
                for &n in &path {
                    assert!(seen.insert(n), "routing loop through {n}: {path:?}");
                }
            }
        }
    });
}

/// Directed link ids are injective over ordered node pairs, stay below
/// `n_links()`, and the reverse hop always maps to a *different* id —
/// per-direction egress queues never alias.
#[test]
fn prop_link_ids_are_unique_and_direction_sensitive() {
    prop("link-id-uniqueness", 40, |rng| {
        let topo = random_topology(rng);
        let n = topo.n_nodes() as NodeId;
        let mut seen = HashSet::new();
        for a in 0..n {
            for b in 0..n {
                let id = topo.link_id(a, b);
                assert!(id < topo.n_links(), "link id {id} escapes n_links");
                assert!(seen.insert(id), "duplicate link id {id} for ({a},{b})");
                if a != b {
                    assert_ne!(
                        topo.link_id(a, b),
                        topo.link_id(b, a),
                        "({a},{b}) aliases its reverse direction"
                    );
                }
            }
        }
        // every host uplink is a routable hop with a consistent parent
        for (host, sw) in topo.host_uplinks() {
            assert!(topo.is_switch(sw), "host {host} parented to non-switch {sw}");
            assert_eq!(topo.parent_of(host), sw);
        }
    });
}

/// ECMP is a pure function of the flow: rebuilding the same fat-tree
/// and re-asking for the same `(at, src, dst)` always yields the same
/// next hop — including from other threads, which is what makes the
/// parallel sweep executor byte-deterministic at any `--threads`.
#[test]
fn prop_ecmp_is_deterministic_across_rebuilds_and_threads() {
    prop("ecmp-determinism", 10, |rng| {
        let racks = rng.uniform_u64(2, 8) as usize;
        let n_hosts = rng.uniform_u64(2, 8) as usize;
        let oversub = rng.uniform_u64(1, 4) as usize;
        let build = move || Topology::fat_tree(racks, n_hosts, 4, oversub);
        let topo = build();
        let eps = endpoints(&topo);
        let table: Vec<(NodeId, NodeId, Vec<NodeId>)> = eps
            .iter()
            .flat_map(|&s| eps.iter().map(move |&d| (s, d)))
            .filter(|(s, d)| s != d)
            .map(|(s, d)| (s, d, topo.walk(s, d, 8).unwrap().0))
            .collect();
        let workers: Vec<_> = (0..4)
            .map(|_| {
                let expect = table.clone();
                std::thread::spawn(move || {
                    let mine = build();
                    for (s, d, path) in &expect {
                        let (got, _) = mine.walk(*s, *d, 8).unwrap();
                        assert_eq!(&got, path, "ECMP diverged for {s} -> {d}");
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().expect("ECMP thread panicked");
        }
    });
}
