//! Property tests for the GF(2^8) field core and the Reed-Solomon share
//! codec behind `esa-fec` (DESIGN.md §16), via the same from-scratch
//! mini-framework as `prop_invariants` (proptest is unavailable
//! offline): the field axioms exhaustively where the domain is small
//! (commutativity, inverses) and by deterministic seeded sweep where it
//! is cubic (associativity, distributivity), then the codec's defining
//! property — encode → erase → decode is the identity for **every**
//! `b`-subset of the `2b - 1` shares, for every `b` in `1..=MAX_B`.
//! On failure, re-run with the printed seed.

use esa::net::fec;
use esa::util::gf256;
use esa::util::rng::Rng;

/// Run `cases` random cases; panic with the failing seed on error.
fn prop(name: &str, cases: u64, mut f: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = 0xFEC0_0000 + case;
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property `{name}` failed at seed {seed:#x} (case {case})");
            std::panic::resume_unwind(e);
        }
    }
}

fn byte(rng: &mut Rng) -> u8 {
    rng.next_below(256) as u8
}

// -------------------------------------------------------------------
// GF(2^8) field axioms
// -------------------------------------------------------------------

#[test]
fn gf256_addition_is_xor_with_identity_zero() {
    // quadratic domain: check exhaustively
    for a in 0..=255u8 {
        assert_eq!(gf256::add(a, 0), a, "0 is the additive identity");
        assert_eq!(gf256::add(a, a), 0, "characteristic 2: every element is its own negative");
        for b in 0..=255u8 {
            assert_eq!(gf256::add(a, b), gf256::add(b, a), "addition commutes");
        }
    }
}

#[test]
fn gf256_multiplication_commutes_with_identities() {
    for a in 0..=255u8 {
        assert_eq!(gf256::mul(a, 1), a, "1 is the multiplicative identity");
        assert_eq!(gf256::mul(a, 0), 0, "0 annihilates");
        for b in 0..=255u8 {
            assert_eq!(gf256::mul(a, b), gf256::mul(b, a), "multiplication commutes");
        }
    }
}

#[test]
fn gf256_every_nonzero_element_round_trips_through_its_inverse() {
    for a in 1..=255u8 {
        let i = gf256::inv(a);
        assert_ne!(i, 0, "inverse of a unit is a unit");
        assert_eq!(gf256::mul(a, i), 1, "a · a⁻¹ = 1 for a = {a}");
        assert_eq!(gf256::inv(i), a, "inversion is an involution for a = {a}");
        assert_eq!(gf256::div(a, a), 1, "a / a = 1 for a = {a}");
        assert_eq!(gf256::div(1, a), i, "1 / a = a⁻¹ for a = {a}");
    }
}

#[test]
fn prop_gf256_multiplication_associates() {
    prop("gf256_mul_assoc", 64, |rng| {
        for _ in 0..4096 {
            let (a, b, c) = (byte(rng), byte(rng), byte(rng));
            assert_eq!(
                gf256::mul(gf256::mul(a, b), c),
                gf256::mul(a, gf256::mul(b, c)),
                "(a·b)·c = a·(b·c) for ({a}, {b}, {c})"
            );
        }
    });
}

#[test]
fn prop_gf256_multiplication_distributes_over_addition() {
    prop("gf256_distrib", 64, |rng| {
        for _ in 0..4096 {
            let (a, b, c) = (byte(rng), byte(rng), byte(rng));
            assert_eq!(
                gf256::mul(a, gf256::add(b, c)),
                gf256::add(gf256::mul(a, b), gf256::mul(a, c)),
                "a·(b+c) = a·b + a·c for ({a}, {b}, {c})"
            );
        }
    });
}

#[test]
fn prop_gf256_pow_is_iterated_multiplication() {
    prop("gf256_pow", 32, |rng| {
        let a = byte(rng);
        let n = rng.next_below(12) as u32;
        let mut acc = 1u8;
        for _ in 0..n {
            acc = gf256::mul(acc, a);
        }
        assert_eq!(gf256::pow(a, n), acc, "pow({a}, {n})");
    });
}

// -------------------------------------------------------------------
// Reed-Solomon share codec
// -------------------------------------------------------------------

/// Concatenate the shares named by `idxs` out of the flat encode buffer.
fn gather(shares: &[u8], idxs: &[u8], sl: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(idxs.len() * sl);
    for &i in idxs {
        out.extend_from_slice(&shares[i as usize * sl..(i as usize + 1) * sl]);
    }
    out
}

/// The codec's contract, exhaustively: for every shard count and every
/// possible surviving `b`-subset of the `2b - 1` shares (all C(2b-1, b)
/// of them — 8788 reconstructions in total), decode is the identity.
#[test]
fn rs_decode_is_the_identity_for_every_b_subset_of_every_b() {
    let mut rng = Rng::new(0x5EED_FEC);
    for b in 1..=fec::MAX_B {
        let n = rng.uniform_u64(1, 96) as usize;
        let data: Vec<u8> = (0..n).map(|_| byte(&mut rng)).collect();
        let sl = fec::share_len(n, b);
        let shares = fec::encode(&data, b);
        let ns = fec::n_shares(b);
        let mut subsets = 0u64;
        for mask in 0u32..(1 << ns) {
            if mask.count_ones() as usize != b {
                continue;
            }
            subsets += 1;
            let idxs: Vec<u8> = (0..ns as u8).filter(|i| mask >> i & 1 == 1).collect();
            let got = fec::reconstruct(b, &idxs, &gather(&shares, &idxs, sl), sl, n);
            assert_eq!(got, data, "b={b} surviving mask={mask:#017b}");
        }
        // C(2b-1, b) subsets actually visited, not an empty loop
        let choose = |n: u64, k: u64| (1..=k).fold(1u64, |acc, i| acc * (n - k + i) / i);
        assert_eq!(subsets, choose(ns as u64, b as u64), "b={b}");
    }
}

/// Random payload lengths and random erasures, with the survivors
/// arriving in arbitrary (shuffled) order — the PS reassembles shares
/// in whatever order the fabric delivers them.
#[test]
fn prop_rs_random_erasures_decode_in_any_arrival_order() {
    prop("rs_erasure", 128, |rng| {
        let b = rng.uniform_u64(1, fec::MAX_B as u64) as usize;
        let n = rng.uniform_u64(1, 256) as usize;
        let data: Vec<u8> = (0..n).map(|_| byte(rng)).collect();
        let sl = fec::share_len(n, b);
        let shares = fec::encode(&data, b);
        let mut order: Vec<u8> = (0..fec::n_shares(b) as u8).collect();
        rng.shuffle(&mut order);
        let idxs = &order[..b]; // unsorted: arrival order, not index order
        let got = fec::reconstruct(b, idxs, &gather(&shares, idxs, sl), sl, n);
        assert_eq!(got, data, "b={b} n={n} survivors={idxs:?}");
    });
}

/// Losing fewer than b shares is free, and the codec never needs more
/// than b: reconstruction from b+1 choices of exactly-b subsets of a
/// single damaged burst all agree.
#[test]
fn prop_rs_any_b_of_the_survivors_agree() {
    prop("rs_agreement", 64, |rng| {
        let b = rng.uniform_u64(2, fec::MAX_B as u64) as usize;
        let n = rng.uniform_u64(b as u64, 128) as usize;
        let data: Vec<u8> = (0..n).map(|_| byte(rng)).collect();
        let sl = fec::share_len(n, b);
        let shares = fec::encode(&data, b);
        let mut order: Vec<u8> = (0..fec::n_shares(b) as u8).collect();
        rng.shuffle(&mut order);
        let survivors = &order[..b + 1]; // one more than needed
        for skip in 0..survivors.len() {
            let idxs: Vec<u8> = survivors
                .iter()
                .enumerate()
                .filter(|&(i, _)| i != skip)
                .map(|(_, &x)| x)
                .collect();
            let got = fec::reconstruct(b, &idxs, &gather(&shares, &idxs, sl), sl, n);
            assert_eq!(got, data, "b={b} survivors={survivors:?} skip={skip}");
        }
    });
}
