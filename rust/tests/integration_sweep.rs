//! Integration: the parallel scenario-sweep engine (`esa sweep`) —
//! thread-count invariance, run-to-run byte stability, file-based
//! configs, and the committed golden snapshot for the CI quick grid
//! (all five INA policies × racks {1, 4}).

use esa::sim::sweep::{run_sweep, SweepConfig};

/// The determinism contract the CI sweep gate enforces end-to-end:
/// identical bytes across two runs AND across `--threads 1` vs N.
#[test]
fn quick_sweep_byte_identical_across_runs_and_thread_counts() {
    let cfg = SweepConfig::quick();
    let a = run_sweep(&cfg, 1).unwrap();
    let b = run_sweep(&cfg, 4).unwrap();
    let c = run_sweep(&cfg, 4).unwrap();
    assert_eq!(a.to_json(), b.to_json(), "threads 1 vs 4 must serialize identically");
    assert_eq!(b.to_json(), c.to_json(), "two identical runs must serialize identically");
    assert_eq!(a.to_csv(), b.to_csv(), "CSV must be byte-stable too");
}

#[test]
fn quick_sweep_covers_five_policies_and_both_fabrics_cleanly() {
    let report = run_sweep(&SweepConfig::quick(), 4).unwrap();
    assert_eq!(report.cells.len(), 10, "5 policies x racks {{1,4}}");
    for cell in &report.cells {
        assert_eq!(cell.truncated, 0, "{:?} stalled", cell.spec);
        assert!(cell.jct_ms_mean > 0.0, "{:?}", cell.spec);
        assert!(cell.events > 0, "{:?}", cell.spec);
    }
    // the two-tier cells actually exercised the edge fold for ESA
    let esa_4racks = report
        .cells
        .iter()
        .find(|c| c.spec.policy.key() == "esa" && c.spec.racks == 4)
        .expect("ESA racks=4 cell");
    assert!(
        esa_4racks.edge_partial_pkts > 0.0,
        "no rack partials reached the edge: {esa_4racks:?}"
    );
}

#[test]
fn file_config_round_trips_through_the_engine() {
    let dir = std::env::temp_dir().join("esa_sweep_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mini.toml");
    std::fs::write(
        &path,
        r#"
        name = "mini"
        iterations = 1
        [axes]
        policies = ["esa"]
        racks = [1]
        workers = [2]
        jobs = [1]
        seeds = [7]
        tensor_kb = [64]
        [models]
        names = ["microbench"]
        "#,
    )
    .unwrap();
    let cfg = SweepConfig::from_file(&path).unwrap();
    let report = run_sweep(&cfg, 2).unwrap();
    assert_eq!(report.cells.len(), 1);
    assert_eq!(report.cells[0].truncated, 0);
    let (json_path, csv_path) = report.write(&dir).unwrap();
    let json = std::fs::read_to_string(&json_path).unwrap();
    assert_eq!(json, report.to_json(), "written artifact must match in-memory bytes");
    let csv = std::fs::read_to_string(&csv_path).unwrap();
    assert_eq!(csv.lines().count(), 2, "header + one cell row");
    assert!(json_path.file_name().unwrap().to_str().unwrap() == "SWEEP_mini.json");
}

#[test]
fn missing_config_file_is_a_pointed_error() {
    let err = SweepConfig::from_file(std::path::Path::new("/nonexistent/sweep.toml"))
        .unwrap_err()
        .to_string();
    assert!(err.contains("reading sweep config"), "{err}");
}

/// The golden gate: the committed snapshot pins the quick grid's bytes.
///
/// Self-blessing harness (`make bless` documents the flow):
/// - `ESA_BLESS=1 cargo test` rewrites the snapshot from a live run and
///   passes — the one sanctioned way to accept intentional drift.
/// - A missing snapshot FAILS (it is a committed artifact, not optional).
/// - A seed `"placeholder"` snapshot (the repo bootstrapped without
///   blessed bytes) is replaced in place by the live bytes and the test
///   passes with a loud "commit the result" — the debt self-heals on the
///   first real test run instead of skipping forever.
/// - Otherwise: strict byte comparison; any drift fails here and in the
///   CI sweep gate.
#[test]
fn quick_sweep_matches_committed_golden() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/sweep_quick.json");
    let fresh = run_sweep(&SweepConfig::quick(), 2).unwrap().to_json();
    assert!(
        fresh.contains("\"provenance\":\"simulated\""),
        "fresh sweep bytes must be self-describing"
    );
    if std::env::var_os("ESA_BLESS").is_some() {
        std::fs::write(&path, &fresh).unwrap();
        eprintln!("blessed {} ({} bytes) — review and commit it", path.display(), fresh.len());
        return;
    }
    let golden = match std::fs::read_to_string(&path) {
        Ok(s) => s,
        Err(e) => panic!(
            "golden snapshot {} is missing ({e}) — run `make bless` and commit the result",
            path.display()
        ),
    };
    if golden.contains("\"placeholder\"") {
        std::fs::write(&path, &fresh).unwrap();
        eprintln!(
            "{} was an unblessed placeholder — regenerated it from a live quick-grid run; \
             review and commit the result",
            path.display()
        );
        return;
    }
    assert_eq!(
        fresh, golden,
        "quick sweep drifted from the blessed golden snapshot — if the change is \
         intentional, regenerate via `make bless` (ESA_BLESS=1) and commit"
    );
}

/// The `esa-k` axis rides the sweep grid like any other policy: cells are
/// distinguished by the parameterized key, run cleanly, and the artifact
/// bytes stay identical across thread counts (the same contract the CI
/// bench-smoke esa-k step enforces end-to-end through the binary).
#[test]
fn esa_k_axis_is_byte_deterministic_across_thread_counts() {
    let cfg = SweepConfig::parse_str(
        r#"
        name = "esa_k_axis"
        iterations = 1
        [axes]
        policies = ["esa", "esa-k=5000", "esa-k=40000"]
        workers = [4]
        jobs = [2]
        seeds = [42]
        tensor_kb = [256]
        [models]
        names = ["microbench"]
        "#,
    )
    .unwrap();
    let a = run_sweep(&cfg, 1).unwrap();
    let b = run_sweep(&cfg, 4).unwrap();
    assert_eq!(a.to_json(), b.to_json(), "esa-k cells must not depend on thread count");
    assert_eq!(a.cells.len(), 3);
    for cell in &a.cells {
        assert_eq!(cell.truncated, 0, "{} stalled", cell.spec.policy.key());
        assert!(cell.jct_ms_mean > 0.0);
    }
    // the parameter is the cell identity: keys survive into the artifact
    let json = a.to_json();
    assert!(json.contains("\"esa-k=5000\""), "{json}");
    assert!(json.contains("\"esa-k=40000\""), "{json}");
}
