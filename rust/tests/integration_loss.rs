//! Failure injection: §5.3's loss cases. The simulator injects i.i.d.
//! per-hop loss; these tests assert that the reminder / dupACK / NACK /
//! cached-result machinery recovers in *every* regime — including with
//! real payload values, where recovery must also preserve exact sums.

use std::sync::Arc;

use esa::config::ExperimentConfig;
use esa::sim::Simulation;
use esa::switch::policy::{atp, esa, hostps, PolicyHandle};

fn cfg(policy: PolicyHandle, loss: f64, jobs: usize, workers: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::synthetic(policy, "microbench", jobs, workers);
    c.iterations = 2;
    c.seed = 1234;
    c.net.loss_prob = loss;
    for j in &mut c.jobs {
        j.tensor_bytes = Some(256 * 1024);
    }
    c
}

#[test]
fn esa_recovers_from_light_loss() {
    let m = Simulation::run_experiment(cfg(esa(), 0.001, 2, 4)).unwrap();
    assert!(!m.truncated);
    assert_eq!(m.jobs.len(), 2);
}

#[test]
fn esa_recovers_from_heavy_loss() {
    // 2% per hop is far beyond any DC reality — a stress test for the
    // reminder machinery (case 1/3/4 + NACK selective retransmission)
    let m = Simulation::run_experiment(cfg(esa(), 0.02, 1, 4)).unwrap();
    assert!(!m.truncated, "reminder machinery must converge under heavy loss");
}

#[test]
fn atp_recovers_via_resend_semantics() {
    let m = Simulation::run_experiment(cfg(atp(), 0.005, 2, 4)).unwrap();
    assert!(!m.truncated);
}

#[test]
fn hostps_recovers_via_ps_machinery() {
    let m = Simulation::run_experiment(cfg(hostps(), 0.005, 2, 4)).unwrap();
    assert!(!m.truncated);
}

#[test]
fn recovery_machinery_actually_fires() {
    let mut c = cfg(esa(), 0.01, 1, 4);
    c.iterations = 1;
    let mut sim = Simulation::new(c).unwrap();
    let m = sim.run();
    assert!(!m.truncated);
    let ps = sim.ps(0);
    let st = &ps.stats;
    assert!(
        st.worker_reminders + st.reminders_to_switch > 0,
        "loss at 1% must trigger reminders"
    );
    assert_eq!(ps.pending_entries(0), 0, "all PS entries must resolve");
}

#[test]
fn loss_preserves_exact_aggregation_values() {
    // The §5.3 headline: *all-case correctness*. Drop 1% of packets and
    // verify the aggregated values still match the wrapping reference
    // exactly — no double-counted retransmissions, no lost contributions.
    let mut c = cfg(esa(), 0.01, 1, 4);
    c.iterations = 1;
    let mut sim = Simulation::new(c).unwrap();
    let frags = 256 * 1024 / 256;
    let lanes = 64;
    let mut reference = vec![0i32; frags * lanes];
    for w in 0..4 {
        let payload: Vec<i32> = (0..frags * lanes)
            .map(|i| (i as i32).wrapping_mul(2654435761u32 as i32).wrapping_add(w))
            .collect();
        esa::util::fixed::agg_add_slice(&mut reference, &payload);
        sim.worker_mut(0, w as usize).set_payload(Arc::new(payload));
    }
    let m = sim.run();
    assert!(!m.truncated);
    let collected = sim.worker_mut(0, 0).take_collected().unwrap();
    let diffs = collected
        .iter()
        .zip(&reference)
        .filter(|(a, b)| a != b)
        .count();
    assert_eq!(diffs, 0, "{diffs} lanes diverged under loss");
}

#[test]
fn atp_loss_preserves_exact_values_too() {
    let mut c = cfg(atp(), 0.01, 1, 4);
    c.iterations = 1;
    let mut sim = Simulation::new(c).unwrap();
    let frags = 256 * 1024 / 256;
    let lanes = 64;
    let mut reference = vec![0i32; frags * lanes];
    for w in 0..4 {
        let payload: Vec<i32> = (0..frags * lanes)
            .map(|i| (i as i32) ^ (w << 20))
            .collect();
        esa::util::fixed::agg_add_slice(&mut reference, &payload);
        sim.worker_mut(0, w as usize).set_payload(Arc::new(payload));
    }
    let m = sim.run();
    assert!(!m.truncated);
    let collected = sim.worker_mut(0, 0).take_collected().unwrap();
    assert_eq!(collected, reference, "ATP resend path must not double count");
}

#[test]
fn loss_with_contention_and_preemption_remains_exact() {
    // the hardest case: loss + preemption + partials merging at the PS
    let mut c = cfg(esa(), 0.005, 2, 4);
    c.switch.memory_bytes = 32 * 1024; // ~117 slots → constant collisions
    c.iterations = 1;
    let mut sim = Simulation::new(c).unwrap();
    let frags = 256 * 1024 / 256;
    let lanes = 64;
    let mut refs = Vec::new();
    for job in 0..2u16 {
        let mut reference = vec![0i32; frags * lanes];
        for w in 0..4 {
            let payload: Vec<i32> = (0..frags * lanes)
                .map(|i| (i as i32).wrapping_mul(13).wrapping_add((job as i32) << 8 | w))
                .collect();
            esa::util::fixed::agg_add_slice(&mut reference, &payload);
            sim.worker_mut(job, w as usize).set_payload(Arc::new(payload));
        }
        refs.push(reference);
    }
    let m = sim.run();
    assert!(!m.truncated);
    for job in 0..2u16 {
        let collected = sim.worker_mut(job, 0).take_collected().unwrap();
        assert_eq!(collected, refs[job as usize], "job {job}");
    }
}

#[test]
fn loss_sweep_jct_degrades_gracefully() {
    // JCT should grow smoothly with loss, not cliff into timeouts
    let mut last = 0.0f64;
    for loss in [0.0, 0.001, 0.01] {
        let m = Simulation::run_experiment(cfg(esa(), loss, 1, 4)).unwrap();
        assert!(!m.truncated, "loss={loss}");
        let jct = m.avg_jct_ms();
        assert!(jct.is_finite());
        if loss == 0.0 {
            last = jct;
        }
        // 1% per-hop loss is ~100 recovery rounds per iteration at the
        // paper's 1 ms RTO floor — large JCT inflation is inherent; the
        // bound catches livelock, not graceful-degradation nuance.
        assert!(
            jct < last * 400.0 + 100.0,
            "loss={loss}: JCT {jct:.3} ms blew up (baseline {last:.3})"
        );
    }
}
