# Convenience targets for the ESA reproduction. The rust simulator is
# self-contained (`cd rust && cargo build`); the python side exists only to
# AOT-lower the training graphs once (`make artifacts`).

ARTIFACTS ?= artifacts
PRESET ?= tiny
WORKERS ?= 4

.PHONY: build test bench bench-figures figures artifacts clean-artifacts

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

## Regenerate every paper figure at quick scale (ESA_BENCH_QUICK=1).
figures: build
	cd rust && ESA_BENCH_QUICK=1 cargo run --release -- figures all

## Hot-path micro-benchmarks; refreshes BENCH_hotpath.json at the repo
## root (the machine-readable perf trajectory — see README § Benchmarks).
bench: build
	cd rust && cargo bench --bench hotpath

## Every figure-regeneration harness (slow, paper scale).
bench-figures: build
	cd rust && cargo bench

## AOT-lower the jax/Pallas graphs to HLO text (needs jax; see DESIGN.md §7).
artifacts:
	cd python && python -m compile.aot --out-dir ../$(ARTIFACTS) --preset $(PRESET) --workers $(WORKERS)

clean-artifacts:
	rm -rf $(ARTIFACTS)
