# Convenience targets for the ESA reproduction. The rust simulator is
# self-contained (`cd rust && cargo build`); the python side exists only to
# AOT-lower the training graphs once (`make artifacts`).

ARTIFACTS ?= artifacts
PRESET ?= tiny
WORKERS ?= 4

.PHONY: build test lint bench bench-figures figures sweep fec collective churn scenario bless artifacts clean-artifacts

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

## The static gate (DESIGN.md §14): esa-lint enforces the determinism /
## architecture / hot-path invariants (writes rust/target/LINT.json),
## then clippy covers the whole workspace at deny-warnings, mirroring
## the CI lint-gate lane.
lint:
	cd rust && cargo run --release -q -p esa-lint -- --root .
	cd rust && cargo clippy --workspace --all-targets -- -D warnings

## Run a scenario sweep on all cores. Default: the built-in quick grid
## (5 INA policies x racks {1,4}); point SWEEP_CONFIG at a sweep TOML for
## a custom grid. Artifacts land in rust/target/sweeps/.
SWEEP_CONFIG ?=
sweep: build
	cd rust && ESA_BENCH_QUICK=1 ./target/release/esa sweep \
		$(if $(SWEEP_CONFIG),--config $(abspath $(SWEEP_CONFIG)),) --out-dir target/sweeps

## Run the committed FEC-vs-retransmit demo grid (DESIGN.md §16): a lossy
## fabric swept over axes.fec_b, so SWEEP_fec.json holds the
## erasure-coded-recovery JCT curve next to the retransmit baseline.
## Artifacts land in rust/target/fec-demo/.
fec: build
	cd rust && ./target/release/esa sweep \
		--config configs/fec_demo.toml --out-dir target/fec-demo

## Run the committed "which collective wins where" demo grid (DESIGN.md
## §17): ps-ina vs pure ring vs the INA-ring hybrid, swept over tensor
## size and fat-tree core oversubscription, so SWEEP_collective.json
## holds the crossover both ways. Artifacts land in
## rust/target/collective-demo/.
collective: build
	cd rust && ./target/release/esa sweep \
		--config configs/collective_demo.toml --out-dir target/collective-demo

## Replay the default Poisson job-churn scenario (runtime admission +
## reclamation) under ESA/ATP/SwitchML; CHURN_quick.json lands in
## rust/target/churn/. Override flags via CHURN_FLAGS="--jobs 20 ...".
CHURN_FLAGS ?=
churn: build
	cd rust && ./target/release/esa churn $(CHURN_FLAGS) --out-dir target/churn

## Replay the default fault-injection scenario (straggler + link flap +
## switch crash + tenant burst) under ESA/ATP/SwitchML with structured
## event capture and a built-in replay check; SCENARIO_quick.json and the
## per-policy .events.jsonl sidecars land in rust/target/scenarios/.
## Point SCENARIO_CONFIG at a scenario TOML for a custom fault timeline,
## or override flags via SCENARIO_FLAGS="--policies esa --seed 9 ...".
SCENARIO_CONFIG ?=
SCENARIO_FLAGS ?=
scenario: build
	cd rust && ./target/release/esa scenario \
		$(if $(SCENARIO_CONFIG),--config $(abspath $(SCENARIO_CONFIG)),) \
		$(SCENARIO_FLAGS) --verify --out-dir target/scenarios

## Regenerate the committed golden snapshots in rust/tests/golden/ from a
## live run, then commit the diff. Goes through the tests themselves
## (ESA_BLESS=1 rewrites each snapshot with exactly the bytes the test
## compares), so the blessed file can never disagree with the gate.
bless:
	cd rust && ESA_BLESS=1 cargo test -q --test integration_sweep quick_sweep_matches_committed_golden
	@echo "blessed rust/tests/golden/ — review the diff and commit it"

## Regenerate every paper figure at quick scale (ESA_BENCH_QUICK=1).
figures: build
	cd rust && ESA_BENCH_QUICK=1 cargo run --release -- figures all

## Hot-path micro-benchmarks; refreshes BENCH_hotpath.json at the repo
## root (the machine-readable perf trajectory — see README § Benchmarks).
bench: build
	cd rust && cargo bench --bench hotpath

## Every figure-regeneration harness (slow, paper scale).
bench-figures: build
	cd rust && cargo bench

## AOT-lower the jax/Pallas graphs to HLO text (needs jax; see DESIGN.md §7).
artifacts:
	cd python && python -m compile.aot --out-dir ../$(ARTIFACTS) --preset $(PRESET) --workers $(WORKERS)

clean-artifacts:
	rm -rf $(ARTIFACTS)
