# Convenience targets for the ESA reproduction. The rust simulator is
# self-contained (`cd rust && cargo build`); the python side exists only to
# AOT-lower the training graphs once (`make artifacts`).

ARTIFACTS ?= artifacts
PRESET ?= tiny
WORKERS ?= 4

.PHONY: build test bench bench-figures figures sweep churn bless artifacts clean-artifacts

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

## Run a scenario sweep on all cores. Default: the built-in quick grid
## (5 INA policies x racks {1,4}); point SWEEP_CONFIG at a sweep TOML for
## a custom grid. Artifacts land in rust/target/sweeps/.
SWEEP_CONFIG ?=
sweep: build
	cd rust && ESA_BENCH_QUICK=1 ./target/release/esa sweep \
		$(if $(SWEEP_CONFIG),--config $(abspath $(SWEEP_CONFIG)),) --out-dir target/sweeps

## Replay the default Poisson job-churn scenario (runtime admission +
## reclamation) under ESA/ATP/SwitchML; CHURN_quick.json lands in
## rust/target/churn/. Override flags via CHURN_FLAGS="--jobs 20 ...".
CHURN_FLAGS ?=
churn: build
	cd rust && ./target/release/esa churn $(CHURN_FLAGS) --out-dir target/churn

## Regenerate the committed golden sweep snapshot (run on real hardware,
## then commit). The CI sweep gate diffs every build against this file.
bless: build
	cd rust && ESA_BENCH_QUICK=1 ./target/release/esa sweep --threads 1 --out-dir target/bless
	cp rust/target/bless/SWEEP_quick.json rust/tests/golden/sweep_quick.json
	@echo "blessed rust/tests/golden/sweep_quick.json — review and commit it"

## Regenerate every paper figure at quick scale (ESA_BENCH_QUICK=1).
figures: build
	cd rust && ESA_BENCH_QUICK=1 cargo run --release -- figures all

## Hot-path micro-benchmarks; refreshes BENCH_hotpath.json at the repo
## root (the machine-readable perf trajectory — see README § Benchmarks).
bench: build
	cd rust && cargo bench --bench hotpath

## Every figure-regeneration harness (slow, paper scale).
bench-figures: build
	cd rust && cargo bench

## AOT-lower the jax/Pallas graphs to HLO text (needs jax; see DESIGN.md §7).
artifacts:
	cd python && python -m compile.aot --out-dir ../$(ARTIFACTS) --preset $(PRESET) --workers $(WORKERS)

clean-artifacts:
	rm -rf $(ARTIFACTS)
