"""L2 model tests: shapes, determinism, gradient flow, fixed-point training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels.quantize import SCALE

jax.config.update("jax_platform_name", "cpu")

CFG = M.PRESETS["tiny"]


def _tokens(key, cfg=CFG):
    return jax.random.randint(key, (cfg.batch, cfg.seq_len + 1), 0, cfg.vocab)


def test_flat_len_is_tile_multiple():
    assert M.flat_len(CFG) % M.FLAT_TILE == 0
    assert M.flat_len(CFG) >= M.param_count(CFG)


def test_flatten_unflatten_roundtrip():
    flat = M.init_params_flat(CFG, jax.random.PRNGKey(0))
    params = M.unflatten(CFG, flat)
    flat2 = M.flatten(CFG, params)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(flat2))


def test_param_shapes_cover_count():
    n = sum(int(np.prod(s)) for _, s in M.param_shapes(CFG))
    assert n == M.param_count(CFG)


def test_forward_loss_finite_and_near_uniform_at_init():
    flat = M.init_params_flat(CFG, jax.random.PRNGKey(0))
    loss = M.forward_loss(CFG, flat, _tokens(jax.random.PRNGKey(1)))
    assert np.isfinite(float(loss))
    # at init the LM should be near the uniform-distribution entropy
    assert abs(float(loss) - np.log(CFG.vocab)) < 1.0


def test_train_step_shapes_and_clip():
    flat = M.init_params_flat(CFG, jax.random.PRNGKey(0))
    loss, qg = M.train_step(CFG, flat, _tokens(jax.random.PRNGKey(1)))
    assert qg.shape == (M.flat_len(CFG),)
    assert qg.dtype == jnp.int32
    # clipped grads: |g| <= 1 so |q| <= SCALE
    assert np.abs(np.asarray(qg)).max() <= SCALE


def test_train_step_deterministic():
    flat = M.init_params_flat(CFG, jax.random.PRNGKey(0))
    t = _tokens(jax.random.PRNGKey(1))
    l1, q1 = M.train_step(CFG, flat, t)
    l2, q2 = M.train_step(CFG, flat, t)
    assert float(l1) == float(l2)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


def test_apply_update_moves_against_gradient():
    flat = M.init_params_flat(CFG, jax.random.PRNGKey(0))
    t = _tokens(jax.random.PRNGKey(1))
    loss0, qg = M.train_step(CFG, flat, t)
    agg = M.aggregate(jnp.stack([qg]), jnp.ones((1, 1), jnp.int32))
    flat1 = M.apply_update(CFG, flat, agg, jnp.float32(1.0))
    loss1 = M.forward_loss(CFG, flat1, t)
    assert float(loss1) < float(loss0)


def test_fixed_point_aggregation_matches_float_mean():
    """INA path (quantize -> sum -> dequant/mean) ~= float gradient mean."""
    flat = M.init_params_flat(CFG, jax.random.PRNGKey(0))
    keys = jax.random.split(jax.random.PRNGKey(2), 4)
    qgs, fgrads = [], []
    for k in keys:
        t = _tokens(k)
        _, qg = M.train_step(CFG, flat, t)
        qgs.append(qg)
        g = jax.grad(lambda pf: M.forward_loss(CFG, pf, t))(flat)
        gn = jnp.sqrt(jnp.sum(g * g) + 1e-12)
        fgrads.append(g * jnp.minimum(1.0, 1.0 / gn))
    agg = M.aggregate(jnp.stack(qgs), jnp.ones((4, 1), jnp.int32))
    ina_mean = np.asarray(agg, np.float64) / SCALE / 4.0
    float_mean = np.asarray(sum(fgrads) / 4.0, np.float64)
    np.testing.assert_allclose(ina_mean, float_mean, atol=1.0 / SCALE)


def test_short_training_reduces_loss():
    """A few INA-aggregated steps on repeated data reduce the loss."""
    cfg = CFG
    flat = M.init_params_flat(cfg, jax.random.PRNGKey(0))
    t = _tokens(jax.random.PRNGKey(3))
    first = None
    for _ in range(5):
        loss, qg = M.train_step(cfg, flat, t)
        if first is None:
            first = float(loss)
        agg = M.aggregate(jnp.stack([qg]), jnp.ones((1, 1), jnp.int32))
        flat = M.apply_update(cfg, flat, agg, jnp.float32(1.0))
    assert float(loss) < first


def test_presets_well_formed():
    for name, cfg in M.PRESETS.items():
        assert cfg.d_model % cfg.n_heads == 0, name
        assert M.flat_len(cfg) % M.FLAT_TILE == 0, name
