"""L1 kernel correctness: Pallas vs pure-jnp oracle.

hypothesis sweeps shapes, value ranges and masks; equality is exact for the
integer kernels (quantize, aggregate) and allclose for dequantize.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    AGG_BLOCK,
    SCALE_BITS,
    aggregate_fragments,
    dequantize_i32_to_f32,
    quantize_f32_to_i32,
)
from compile.kernels.quantize import I32_MAX, I32_MIN, SCALE
from compile.kernels.ref import aggregate_ref, dequantize_ref, quantize_ref

jax.config.update("jax_platform_name", "cpu")


# --------------------------------------------------------------------------
# quantize / dequantize
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    rows=st.sampled_from([8, 16, 32]),
    cols=st.sampled_from([128, 256, 512]),
    scale=st.floats(min_value=1e-3, max_value=10.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_quantize_matches_ref(rows, cols, scale, seed):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((rows, cols)) * scale).astype(np.float32)
    got = quantize_f32_to_i32(jnp.asarray(x))
    want = quantize_ref(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    rows=st.sampled_from([8, 24]),
    cols=st.sampled_from([128, 384]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_dequantize_matches_ref(rows, cols, seed):
    rng = np.random.default_rng(seed)
    q = rng.integers(I32_MIN, I32_MAX, size=(rows, cols), dtype=np.int32)
    got = dequantize_i32_to_f32(jnp.asarray(q))
    want = dequantize_ref(jnp.asarray(q))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_quantize_saturates():
    x = jnp.asarray([[3e6, -3e6] + [0.0] * 126] * 8, jnp.float32)
    q = np.asarray(quantize_f32_to_i32(x))
    assert q[0, 0] == I32_MAX
    assert q[0, 1] == I32_MIN


def test_roundtrip_error_bound():
    """|dequant(quant(x)) - x| <= 0.5/SCALE for in-range x."""
    rng = np.random.default_rng(0)
    x = rng.uniform(-100, 100, size=(8, 256)).astype(np.float32)
    rt = np.asarray(dequantize_i32_to_f32(quantize_f32_to_i32(jnp.asarray(x))))
    np.testing.assert_allclose(rt, x, atol=0.5 / SCALE + 1e-6 * np.abs(x).max())


def test_quantize_is_linear_enough_for_summation():
    """sum of quantized ~= quantize of sum (the INA correctness premise)."""
    rng = np.random.default_rng(1)
    xs = rng.standard_normal((4, 8, 128)).astype(np.float32)
    q_sum = sum(np.asarray(quantize_f32_to_i32(jnp.asarray(x)), dtype=np.int64) for x in xs)
    direct = np.asarray(quantize_ref(jnp.asarray(xs.sum(axis=0))), dtype=np.int64)
    # each term contributes at most 0.5 ulp of rounding error
    assert np.abs(q_sum - direct).max() <= len(xs)


# --------------------------------------------------------------------------
# aggregate
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([8, 16, 32]),
    blocks=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_aggregate_matches_ref(n, blocks, seed):
    rng = np.random.default_rng(seed)
    f = blocks * AGG_BLOCK
    q = rng.integers(-(2**24), 2**24, size=(n, f), dtype=np.int32)
    mask = rng.integers(0, 2, size=(n, 1), dtype=np.int32)
    got = aggregate_fragments(jnp.asarray(q), jnp.asarray(mask))
    want = aggregate_ref(jnp.asarray(q), jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_aggregate_empty_mask_is_zero():
    q = jnp.ones((8, AGG_BLOCK), jnp.int32) * 12345
    mask = jnp.zeros((8, 1), jnp.int32)
    out = np.asarray(aggregate_fragments(q, mask))
    assert (out == 0).all()


def test_aggregate_partial_then_rest_equals_full():
    """Preemption invariant: agg(first half) + agg(second half) == agg(all).

    This is the exact property ESA's partial-result forwarding relies on
    (the PS adds partials; §5.1 case 1).
    """
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.integers(-(2**20), 2**20, size=(8, AGG_BLOCK), dtype=np.int32))
    m_first = jnp.asarray(np.array([[1], [1], [1], [0], [0], [0], [0], [0]], np.int32))
    m_rest = 1 - m_first
    m_all = jnp.ones((8, 1), jnp.int32)
    a = np.asarray(aggregate_fragments(q, m_first))
    b = np.asarray(aggregate_fragments(q, m_rest))
    full = np.asarray(aggregate_fragments(q, m_all))
    np.testing.assert_array_equal(a + b, full)


def test_aggregate_wraparound_is_two_complement():
    """i32 overflow must wrap (switch ALU + rust wrapping_add semantics)."""
    q = np.zeros((8, AGG_BLOCK), np.int32)
    q[0, 0] = np.int32(2**31 - 1)
    q[1, 0] = np.int32(1)
    mask = np.ones((8, 1), np.int32)
    out = np.asarray(aggregate_fragments(jnp.asarray(q), jnp.asarray(mask)))
    assert out[0, 0] == np.int32(-(2**31))


def test_aggregate_rejects_bad_shapes():
    with pytest.raises(AssertionError):
        aggregate_fragments(jnp.zeros((7, AGG_BLOCK), jnp.int32), jnp.zeros((7, 1), jnp.int32))
    with pytest.raises(AssertionError):
        aggregate_fragments(jnp.zeros((8, 100), jnp.int32), jnp.zeros((8, 1), jnp.int32))
