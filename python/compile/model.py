"""Layer-2: JAX model + training-step graphs, AOT-lowered for the rust side.

The paper trains ResNet50/VGG16 over BytePS; what its evaluation actually
depends on is (a) a real DNN producing real gradients and (b) the INA
fixed-point aggregation path being numerically faithful. We stand in a
decoder-only transformer LM (the modern canonical distributed-training
workload) with fully configurable size, and expose four AOT graphs the rust
coordinator drives through PJRT:

  train_step(params_flat, tokens)       -> (loss, qgrads)       [per worker]
  aggregate(qgrads_stacked, mask)       -> agg_i32              [switch/PS ALU]
  apply_update(params_flat, agg, fanin) -> params_flat'         [pull + SGD]
  fwd_loss(params_flat, tokens)         -> loss                 [eval]

``train_step`` quantizes gradients with the L1 Pallas kernel *inside* the
jitted graph (workers quantize before fragmenting, §5.1), so the Pallas
kernel lowers into the same HLO artifact. ``aggregate`` wraps the L1
aggregation kernel. All parameters travel as one flat f32 vector padded to
a (8,128) tile multiple, which keeps the rust FFI to plain 1-D/2-D arrays.
"""

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.aggregate import aggregate_fragments
from compile.kernels.quantize import (
    SCALE_BITS,
    dequantize_i32_to_f32,
    quantize_f32_to_i32,
)

FLAT_TILE = 8 * 128  # params_flat is padded to a multiple of one (8,128) tile


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer LM hyper-parameters (a preset per experiment scale)."""

    vocab: int = 256
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    seq_len: int = 64
    batch: int = 4
    d_ff_mult: int = 4
    lr: float = 0.05

    @property
    def d_ff(self) -> int:
        return self.d_model * self.d_ff_mult

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


PRESETS: Dict[str, ModelConfig] = {
    # artifact default: fast enough for CPU CI and the e2e example
    "tiny": ModelConfig(vocab=256, d_model=128, n_layers=2, n_heads=4, seq_len=64, batch=4),
    # heavier preset for the training bench
    "small": ModelConfig(vocab=512, d_model=256, n_layers=4, n_heads=8, seq_len=128, batch=8),
    # ~100M-class preset (compile-only on this CPU testbed; documented in EXPERIMENTS.md)
    "base": ModelConfig(vocab=8192, d_model=768, n_layers=12, n_heads=12, seq_len=256, batch=8),
}


# ---------------------------------------------------------------------------
# Parameter pytree <-> flat vector
# ---------------------------------------------------------------------------

def param_shapes(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) list — the flattening order contract."""
    shapes: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed", (cfg.vocab, cfg.d_model)),
        ("pos", (cfg.seq_len, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        shapes += [
            (p + "ln1_scale", (cfg.d_model,)),
            (p + "ln1_bias", (cfg.d_model,)),
            (p + "wqkv", (cfg.d_model, 3 * cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2_scale", (cfg.d_model,)),
            (p + "ln2_bias", (cfg.d_model,)),
            (p + "w1", (cfg.d_model, cfg.d_ff)),
            (p + "w2", (cfg.d_ff, cfg.d_model)),
        ]
    shapes += [
        ("lnf_scale", (cfg.d_model,)),
        ("lnf_bias", (cfg.d_model,)),
    ]
    return shapes


def param_count(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_shapes(cfg))


def flat_len(cfg: ModelConfig) -> int:
    """Padded flat-vector length (multiple of one (8,128) tile)."""
    n = param_count(cfg)
    return ((n + FLAT_TILE - 1) // FLAT_TILE) * FLAT_TILE


def unflatten(cfg: ModelConfig, flat: jax.Array) -> Dict[str, jax.Array]:
    params = {}
    off = 0
    for name, shape in param_shapes(cfg):
        size = 1
        for s in shape:
            size *= s
        params[name] = flat[off : off + size].reshape(shape)
        off += size
    return params


def flatten(cfg: ModelConfig, params: Dict[str, jax.Array]) -> jax.Array:
    parts = [params[name].reshape(-1) for name, _ in param_shapes(cfg)]
    flat = jnp.concatenate(parts)
    pad = flat_len(cfg) - flat.shape[0]
    return jnp.pad(flat, (0, pad))


def init_params_flat(cfg: ModelConfig, key: jax.Array) -> jax.Array:
    """Scaled-normal init, returned in flat padded form."""
    params = {}
    for name, shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_scale",)):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(("_bias",)):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 0.02 if name in ("embed", "pos") else 1.0 / jnp.sqrt(fan_in)
            params[name] = jax.random.normal(sub, shape, jnp.float32) * std
    return flatten(cfg, params)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _layernorm(x, scale, bias):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * scale + bias


def _attention(cfg: ModelConfig, x, wqkv, wo):
    b, s, d = x.shape
    qkv = x @ wqkv  # [b, s, 3d]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):
        return t.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    logits = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(float(cfg.head_dim))
    mask = jnp.tril(jnp.ones((s, s), jnp.bool_))
    logits = jnp.where(mask, logits, -1e30)
    attn = jax.nn.softmax(logits, axis=-1)
    out = (attn @ v).transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ wo


def forward_loss(cfg: ModelConfig, params_flat: jax.Array, tokens: jax.Array) -> jax.Array:
    """Next-token cross-entropy of the LM on ``tokens`` (i32[batch, seq+1])."""
    p = unflatten(cfg, params_flat)
    inputs = tokens[:, :-1]
    targets = tokens[:, 1:]
    x = p["embed"][inputs] + p["pos"][None, : inputs.shape[1], :]
    for i in range(cfg.n_layers):
        l = f"layer{i}."
        h = _layernorm(x, p[l + "ln1_scale"], p[l + "ln1_bias"])
        x = x + _attention(cfg, h, p[l + "wqkv"], p[l + "wo"])
        h = _layernorm(x, p[l + "ln2_scale"], p[l + "ln2_bias"])
        x = x + jax.nn.gelu(h @ p[l + "w1"]) @ p[l + "w2"]
    x = _layernorm(x, p["lnf_scale"], p["lnf_bias"])
    logits = x @ p["embed"].T  # weight tying
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


# ---------------------------------------------------------------------------
# AOT graph entry points
# ---------------------------------------------------------------------------

def _as_tile2d(flat: jax.Array) -> jax.Array:
    """View the flat vector as [n/128, 128] for the (8,128)-blocked kernels."""
    return flat.reshape(-1, 128)


def train_step(cfg: ModelConfig, params_flat: jax.Array, tokens: jax.Array):
    """Per-worker step: loss + gradients, quantized by the L1 Pallas kernel.

    Gradient clipping to unit L2 norm bounds |g| so the fixed-point format
    cannot saturate during aggregation (headroom analysis in quantize.py).
    """
    loss, grads = jax.value_and_grad(
        lambda pf: forward_loss(cfg, pf, tokens)
    )(params_flat)
    gnorm = jnp.sqrt(jnp.sum(grads * grads) + 1e-12)
    grads = grads * jnp.minimum(1.0, 1.0 / gnorm)
    qgrads = quantize_f32_to_i32(_as_tile2d(grads))
    return loss, qgrads.reshape(-1)


def aggregate(qgrads: jax.Array, mask: jax.Array) -> jax.Array:
    """Switch/PS ALU batch form: masked i32 sum over the worker axis.

    qgrads: i32[N, P] stacked worker gradients; mask: i32[N, 1].
    N is padded to the kernel's sublane multiple with zero-masked rows.
    """
    n = qgrads.shape[0]
    pad = (-n) % 8
    if pad:
        qgrads = jnp.pad(qgrads, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    return aggregate_fragments(qgrads, mask).reshape(-1)


def apply_update(cfg: ModelConfig, params_flat: jax.Array, agg: jax.Array, fanin: jax.Array):
    """Pull path: dequantize the aggregated fixed-point sum, average, SGD."""
    g2d = dequantize_i32_to_f32(_as_tile2d(agg))
    mean_grad = g2d.reshape(-1) / fanin
    return params_flat - cfg.lr * mean_grad


def make_entry_points(cfg: ModelConfig, n_workers: int):
    """Jitted entry points with example args, ready for AOT lowering."""
    p = flat_len(cfg)
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32)
    pf = jax.ShapeDtypeStruct((p,), jnp.float32)
    qg = jax.ShapeDtypeStruct((n_workers, p), jnp.int32)
    mk = jax.ShapeDtypeStruct((n_workers, 1), jnp.int32)
    ag = jax.ShapeDtypeStruct((p,), jnp.int32)
    fanin = jax.ShapeDtypeStruct((), jnp.float32)

    return {
        "train_step": (jax.jit(functools.partial(train_step, cfg)), (pf, tok)),
        "fwd_loss": (jax.jit(functools.partial(forward_loss, cfg)), (pf, tok)),
        "aggregate": (jax.jit(aggregate), (qg, mk)),
        "apply_update": (jax.jit(functools.partial(apply_update, cfg)), (pf, ag, fanin)),
    }
