"""Pure-jnp oracles for every L1 kernel.

These are the correctness ground truth: the Pallas kernels must match them
exactly (integer ops) or to float ulp (dequantize). The rust
``util/fixed.rs`` codec is additionally cross-checked against the AOT HLO
of these functions in ``rust/tests/integration_runtime.rs``.
"""

import jax.numpy as jnp

from compile.kernels.quantize import I32_MAX, I32_MIN, SCALE


def quantize_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Reference: saturating round-to-nearest-even fixed-point quantize."""
    scaled = jnp.clip(jnp.round(x * SCALE), float(I32_MIN), float(I32_MAX))
    return scaled.astype(jnp.int32)


def dequantize_ref(q: jnp.ndarray) -> jnp.ndarray:
    """Reference: fixed-point to float."""
    return q.astype(jnp.float32) * (1.0 / SCALE)


def aggregate_ref(q: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Reference: masked wrap-around i32 column sum, keepdims."""
    return jnp.sum(q * mask, axis=0, keepdims=True)
