"""Float <-> fixed-point conversion kernels (paper §5.1).

Programmable switches cannot add floats, so SwitchML/ATP/ESA convert each
gradient value to a 32-bit fixed-point integer at the end host before the
fragment is put on the wire, and convert the aggregated integer back to
float after the pull. We use a power-of-two scale (``2**SCALE_BITS``) so
the conversion is exact to document and cheap to mirror bit-for-bit in the
rust coordinator (``rust/src/util/fixed.rs``).

Quantize:    q = clamp(round(x * 2**SCALE_BITS), i32_min, i32_max)
Dequantize:  x = q / 2**SCALE_BITS

The kernels are written for TPU shape discipline — last dim a multiple of
128, second-to-last of 8 — and run under ``interpret=True`` so they lower
to plain HLO the CPU PJRT client can execute (see DESIGN.md
§Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 2**SCALE_BITS is the fixed-point scale. ATP uses a per-packet exponent;
# we follow SwitchML's simpler global scale, which is sufficient because
# gradients are pre-normalised by the L2 train step. 20 fractional bits
# leave 11 integer bits of headroom for the fan-in sum (up to 2048 workers
# at |g| <= 1).
SCALE_BITS = 20
SCALE = float(1 << SCALE_BITS)

I32_MIN = -(2**31)
I32_MAX = 2**31 - 1

# Lane/sublane tile the kernels are blocked on (TPU VPU register shape).
QUANT_BLOCK = (8, 128)


def _quantize_kernel(x_ref, q_ref):
    """One (8,128) VMEM block: float32 -> saturating fixed-point int32."""
    x = x_ref[...]
    scaled = x * SCALE
    # Saturate before the cast: jnp.int32 cast of out-of-range floats is
    # implementation-defined; the switch ALU semantics we model saturate.
    scaled = jnp.clip(jnp.round(scaled), float(I32_MIN), float(I32_MAX))
    q_ref[...] = scaled.astype(jnp.int32)


def _dequantize_kernel(q_ref, x_ref):
    """One (8,128) VMEM block: fixed-point int32 -> float32."""
    q = q_ref[...]
    x_ref[...] = q.astype(jnp.float32) * (1.0 / SCALE)


def _grid_for(shape):
    rows, cols = shape
    br, bc = QUANT_BLOCK
    assert rows % br == 0 and cols % bc == 0, (
        f"quantize kernels require shapes padded to {QUANT_BLOCK}, got {shape}"
    )
    return (rows // br, cols // bc)


@functools.partial(jax.jit, static_argnames=())
def quantize_f32_to_i32(x: jax.Array) -> jax.Array:
    """Quantize a 2-D f32 array to fixed-point i32 (Pallas, interpret mode).

    The array is streamed through VMEM in (8,128) blocks — the HBM->VMEM
    schedule a TPU build would use; interpret mode preserves the numerics.
    """
    grid = _grid_for(x.shape)
    return pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(QUANT_BLOCK, lambda i, j: (i, j))],
        out_specs=pl.BlockSpec(QUANT_BLOCK, lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.int32),
        interpret=True,
    )(x)


@functools.partial(jax.jit, static_argnames=())
def dequantize_i32_to_f32(q: jax.Array) -> jax.Array:
    """Dequantize a 2-D fixed-point i32 array back to f32."""
    grid = _grid_for(q.shape)
    return pl.pallas_call(
        _dequantize_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(QUANT_BLOCK, lambda i, j: (i, j))],
        out_specs=pl.BlockSpec(QUANT_BLOCK, lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(q.shape, jnp.float32),
        interpret=True,
    )(q)
