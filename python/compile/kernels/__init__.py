"""Layer-1 Pallas kernels for the ESA reproduction.

These implement, as TPU-shaped Pallas kernels (run with interpret=True on
the CPU PJRT backend), the numeric operations the paper places on hardware:

- ``aggregate``  — the switch aggregator ALU: masked integer summation of
  worker gradient fragments (fixed point, wrap-around i32 add, exactly what
  a Tofino register ALU performs).
- ``quantize`` / ``dequantize`` — the end-host float -> fixed-point
  conversion of SwitchML/ATP/ESA (§5.1 of the paper).

Every kernel has a pure-jnp oracle in :mod:`compile.kernels.ref` and a
hypothesis test sweep in ``python/tests/test_kernel.py``.
"""

from compile.kernels.aggregate import aggregate_fragments, AGG_BLOCK
from compile.kernels.quantize import (
    quantize_f32_to_i32,
    dequantize_i32_to_f32,
    SCALE_BITS,
)

__all__ = [
    "aggregate_fragments",
    "quantize_f32_to_i32",
    "dequantize_i32_to_f32",
    "AGG_BLOCK",
    "SCALE_BITS",
]
