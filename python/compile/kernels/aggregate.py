"""The switch-aggregator ALU as a Pallas kernel.

An INA aggregator performs, per gradient fragment, the integer summation

    value[f] = sum_{w in arrived_workers} q_w[f]        (wrap-around i32)

over fan-in ``N`` workers. On a Tofino this is one register ALU add per
packet; here we express the *batch* form — aggregating a whole fragment
matrix in one pass — as the compute hot-spot the rust data plane invokes
through PJRT, and as the oracle for the per-packet adds the simulator
performs.

The kernel consumes:
  - ``q``    : i32[N, F]  quantized fragments, one row per worker;
  - ``mask`` : i32[N, 1]  bitmap row-mask (1 = worker arrived, 0 = absent),
               mirroring the aggregator's 32-bit arrival bitmap so that
               *partial* aggregation (the thing ESA's preemption produces)
               is expressible;
and produces ``i32[1, F]`` — the aggregator value register contents.

TPU shape discipline: the worker axis N is padded to 8 (sublane), the
fragment axis F blocked at 512 lanes; accumulation is wrap-around int32,
matching both the P4 register ALU and rust's ``wrapping_add``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fragment-axis block width (lanes). 512 = 4 VPU registers deep; one block
# of 8 workers x 512 lanes x 4 B = 16 KiB of VMEM per operand — far under
# the ~16 MiB VMEM budget, leaving room for double buffering on real TPU.
AGG_BLOCK = 512

# Sublane padding for the worker axis.
WORKER_PAD = 8


def _aggregate_kernel(q_ref, mask_ref, out_ref):
    """One (N, AGG_BLOCK) block: masked wrap-around i32 column sum."""
    q = q_ref[...]                      # i32[N, B]
    mask = mask_ref[...]                # i32[N, 1]
    masked = q * mask                   # broadcast over lanes; absent rows -> 0
    # keepdims so the output keeps a (1, B) shape = the value register row.
    out_ref[...] = jnp.sum(masked, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=())
def aggregate_fragments(q: jax.Array, mask: jax.Array) -> jax.Array:
    """Aggregate quantized fragments from up to N workers (Pallas).

    Args:
      q:    i32[N, F] fragment matrix, N % 8 == 0, F % AGG_BLOCK == 0.
      mask: i32[N, 1] arrival bitmap as a column of 0/1.

    Returns:
      i32[1, F] aggregated value register.
    """
    n, f = q.shape
    assert n % WORKER_PAD == 0, f"worker axis must be padded to {WORKER_PAD}, got {n}"
    assert f % AGG_BLOCK == 0, f"fragment axis must be a multiple of {AGG_BLOCK}, got {f}"
    assert mask.shape == (n, 1), f"mask must be [N,1], got {mask.shape}"
    grid = (f // AGG_BLOCK,)
    return pl.pallas_call(
        _aggregate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, AGG_BLOCK), lambda j: (0, j)),
            pl.BlockSpec((n, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, AGG_BLOCK), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, f), jnp.int32),
        interpret=True,
    )(q, mask)
