"""AOT lowering: jax graphs -> HLO *text* artifacts for the rust runtime.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange format:
the image's xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit
instruction ids; the text parser on the rust side
(``HloModuleProto::from_text_file``) reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Each artifact ``<name>.hlo.txt`` gets a sidecar ``<name>.meta`` describing
its I/O shapes in a line format the rust artifact registry parses without a
JSON dependency:

    name=train_step
    input=params_flat f32 164864
    input=tokens i32 4x65
    output=loss f32 -
    output=qgrads i32 164864
    key=value...          # scalar metadata (scale_bits, param_count, ...)

Usage:  cd python && python -m compile.aot --out-dir ../artifacts [--preset tiny]
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels.quantize import SCALE_BITS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dims(shape) -> str:
    if len(shape) == 0:
        return "-"
    return "x".join(str(d) for d in shape)


def _dtype_tag(dt) -> str:
    return {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[jnp.dtype(dt)]


def lower_and_write(name: str, fn, example_args, out_dir: str, extra_meta=None):
    lowered = jax.jit(fn).lower(*example_args) if not hasattr(fn, "lower") else fn.lower(*example_args)
    text = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)

    meta_lines = [f"name={name}"]
    for i, a in enumerate(example_args):
        meta_lines.append(f"input=arg{i} {_dtype_tag(a.dtype)} {_dims(a.shape)}")
    out_tree = jax.eval_shape(fn, *example_args)
    leaves = jax.tree_util.tree_leaves(out_tree)
    for i, leaf in enumerate(leaves):
        meta_lines.append(f"output=out{i} {_dtype_tag(leaf.dtype)} {_dims(leaf.shape)}")
    for k, v in (extra_meta or {}).items():
        meta_lines.append(f"{k}={v}")
    with open(os.path.join(out_dir, f"{name}.meta"), "w") as f:
        f.write("\n".join(meta_lines) + "\n")
    print(f"  wrote {name}: {len(text)} chars HLO, {len(meta_lines)} meta lines")


def build_artifacts(out_dir: str, preset: str, n_workers: int, seed: int = 0):
    cfg = M.PRESETS[preset]
    os.makedirs(out_dir, exist_ok=True)

    entries = M.make_entry_points(cfg, n_workers)
    common_meta = {
        "preset": preset,
        "scale_bits": SCALE_BITS,
        "param_count": M.param_count(cfg),
        "flat_len": M.flat_len(cfg),
        "n_workers": n_workers,
        "vocab": cfg.vocab,
        "seq_len": cfg.seq_len,
        "batch": cfg.batch,
        "lr": cfg.lr,
    }
    for name, (fn, args) in entries.items():
        lower_and_write(name, fn, args, out_dir, extra_meta=common_meta)

    # Initial parameters as a raw little-endian f32 blob — the rust trainer
    # starts every worker from the same deterministic point.
    init = M.init_params_flat(cfg, jax.random.PRNGKey(seed))
    init_path = os.path.join(out_dir, "init_params.f32")
    import numpy as np

    np.asarray(init, dtype="<f4").tofile(init_path)
    print(f"  wrote init_params.f32: {init.shape[0]} f32 values")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="tiny", choices=sorted(M.PRESETS))
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print(f"AOT-lowering preset={args.preset} workers={args.workers} -> {args.out_dir}")
    build_artifacts(args.out_dir, args.preset, args.workers, args.seed)
    print("done")


if __name__ == "__main__":
    main()
